//! Timing helpers: a stopwatch plus the per-phase accumulator used for the
//! paper's Figure 3 time breakdown (Matrix-Multiplication / Solve /
//! Sampling categories, §5.2).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Simple stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Named phase accumulator. Phases are the Fig. 3 categories plus anything
/// an algorithm wants to report.
#[derive(Default, Clone, Debug)]
pub struct PhaseTimer {
    totals: BTreeMap<&'static str, Duration>,
}

/// Canonical phase names (paper Fig. 3).
pub const PHASE_MM: &str = "matmul";
pub const PHASE_SOLVE: &str = "solve";
pub const PHASE_SAMPLING: &str = "sampling";
pub const PHASE_OTHER: &str = "other";

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under a phase label.
    pub fn time<T>(&mut self, phase: &'static str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.add(phase, t.elapsed());
        out
    }

    pub fn add(&mut self, phase: &'static str, d: Duration) {
        *self.totals.entry(phase).or_default() += d;
    }

    pub fn get_secs(&self, phase: &str) -> f64 {
        self.totals
            .iter()
            .find(|(k, _)| **k == phase)
            .map(|(_, v)| v.as_secs_f64())
            .unwrap_or(0.0)
    }

    pub fn total_secs(&self) -> f64 {
        self.totals.values().map(|d| d.as_secs_f64()).sum()
    }

    /// Merge another timer into this one.
    pub fn merge(&mut self, other: &PhaseTimer) {
        for (k, v) in &other.totals {
            *self.totals.entry(k).or_default() += *v;
        }
    }

    pub fn phases(&self) -> impl Iterator<Item = (&'static str, f64)> + '_ {
        self.totals.iter().map(|(k, v)| (*k, v.as_secs_f64()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_timer_accumulates() {
        let mut pt = PhaseTimer::new();
        pt.time(PHASE_MM, || std::thread::sleep(Duration::from_millis(5)));
        pt.time(PHASE_MM, || std::thread::sleep(Duration::from_millis(5)));
        pt.time(PHASE_SOLVE, || ());
        assert!(pt.get_secs(PHASE_MM) >= 0.009);
        assert!(pt.get_secs(PHASE_SOLVE) >= 0.0);
        assert!(pt.total_secs() >= pt.get_secs(PHASE_MM));
    }

    #[test]
    fn merge_sums() {
        let mut a = PhaseTimer::new();
        a.add(PHASE_MM, Duration::from_millis(10));
        let mut b = PhaseTimer::new();
        b.add(PHASE_MM, Duration::from_millis(15));
        b.add(PHASE_SAMPLING, Duration::from_millis(1));
        a.merge(&b);
        assert!((a.get_secs(PHASE_MM) - 0.025).abs() < 1e-9);
        assert!((a.get_secs(PHASE_SAMPLING) - 0.001).abs() < 1e-9);
    }
}
