//! Per-figure experiment definitions (the DESIGN.md §4 index): each
//! function returns the method list + options matching one table/figure
//! of the paper's §5, scaled to this testbed (DESIGN.md §3).

use crate::coordinator::driver::Method;
use crate::data::corpus::{generate, tfidf, Corpus, CorpusParams};
use crate::data::edvw::edvw_adjacency;
use crate::data::sbm::{generate as sbm_generate, SbmGraph, SbmParams};
use crate::linalg::DenseMat;
use crate::nls::UpdateRule;
use crate::sparse::CsrMat;
use crate::symnmf::options::{PowerIter, SymNmfOptions, Tau};

/// The WoS-substitute workload (§5.1): planted-topic corpus → tf-idf →
/// EDVW hypergraph expansion → dense symmetric adjacency. k = 7 topics.
pub struct WosWorkload {
    pub adjacency: DenseMat,
    pub labels: Vec<usize>,
    pub corpus: Corpus,
    pub tfidf: CsrMat,
}

pub fn wos_workload(num_docs: usize, seed: u64) -> WosWorkload {
    // Noise level chosen so clustering is non-trivial (the paper's WoS
    // ARIs sit around 0.31): most tokens are shared background, documents
    // are short, and anchor vocabularies overlap through the background.
    let params = CorpusParams {
        num_docs,
        num_terms: (2 * num_docs).max(500),
        num_topics: 7,
        doc_len: 30,
        noise: 0.65,
        topic_mix: 0.45,
        seed,
    };
    let corpus = generate(&params);
    let w = tfidf(&corpus.counts);
    let adjacency = edvw_adjacency(&w);
    WosWorkload { adjacency, labels: corpus.labels.clone(), corpus, tfidf: w }
}

/// The OAG-substitute workload (§5.2): skewed SBM, symmetrically
/// normalized, zeroed diagonal. k = 16. The core block holds ~93% of the
/// vertices, mirroring the paper's finding that HALS on the OAG produces
/// one giant cluster plus 15 small ones (§5.2.1); the small clusters are
/// what give rows high leverage and feed the hybrid sampler (Fig. 6).
pub fn oag_workload(m: usize, seed: u64) -> SbmGraph {
    // Calibration (DESIGN.md §3):
    // * core_frac 0.96 mirrors the paper's finding of one giant cluster +
    //   15 small ones (§5.2.1) AND puts the small clusters' row leverage
    //   (≈ 1/cluster_size) above the τ·k = k/s hybrid threshold, so the
    //   deterministic sampler captures them (Fig. 6's θ/k → 1).
    // * the dense core (degree 45) vs sparse small blocks (degree 8):
    //   symmetric normalization then gives small-block edges ~5× the
    //   per-edge weight, so the planted signal carries ~15% of ‖X‖² —
    //   large enough to sit above the sampled-product noise floor at
    //   s = 0.05·m, which at the paper's scale (m = 37.7M) holds
    //   automatically because absolute sample counts are 1,900× larger.
    let params = SbmParams::skewed(m, 16, 0.96, seed)
        .with_degrees(8.0, 1.5)
        .with_core_degree(45.0);
    let mut g = sbm_generate(&params);
    crate::sparse::sym::prepare_adjacency(&mut g.adj);
    g
}

/// Base options for the WoS experiments (§5.1): k=7, α=max(X), Ada-RRF,
/// ρ=2k, stopping 1e-4×4.
pub fn wos_options() -> SymNmfOptions {
    SymNmfOptions::new(7)
}

/// Base options for the OAG experiments (§5.2): k=16, s=⌈0.05 m⌉.
pub fn oag_options() -> SymNmfOptions {
    SymNmfOptions::new(16)
}

/// Fig. 1 + Table 2 method list: {BPP, HALS, PGNCG} × {plain, LAI,
/// LAI-IR, Comp}.
pub fn fig1_table2_methods() -> Vec<Method> {
    vec![
        Method::Pgncg,
        Method::LaiPgncg { refine: false },
        Method::LaiPgncg { refine: true },
        Method::Exact(UpdateRule::Bpp),
        Method::Lai { rule: UpdateRule::Bpp, refine: false },
        Method::Lai { rule: UpdateRule::Bpp, refine: true },
        Method::Comp(UpdateRule::Bpp),
        Method::Exact(UpdateRule::Hals),
        Method::Lai { rule: UpdateRule::Hals, refine: false },
        Method::Lai { rule: UpdateRule::Hals, refine: true },
        Method::Comp(UpdateRule::Hals),
    ]
}

/// Fig. 2 method list: HALS/BPP × {plain, LvS τ=1, LvS τ=1/s, LAI}.
pub fn fig2_methods() -> Vec<Method> {
    vec![
        Method::Exact(UpdateRule::Hals),
        Method::Lvs { rule: UpdateRule::Hals, tau: Tau::Fixed(1.0) },
        Method::Lvs { rule: UpdateRule::Hals, tau: Tau::OneOverS },
        Method::Lai { rule: UpdateRule::Hals, refine: false },
        Method::Exact(UpdateRule::Bpp),
        Method::Lvs { rule: UpdateRule::Bpp, tau: Tau::Fixed(1.0) },
        Method::Lvs { rule: UpdateRule::Bpp, tau: Tau::OneOverS },
        Method::Lai { rule: UpdateRule::Bpp, refine: false },
    ]
}

/// Fig. 3 method list: HALS, LvS-HALS, LvS-BPP (time breakdown).
pub fn fig3_methods() -> Vec<Method> {
    vec![
        Method::Exact(UpdateRule::Hals),
        Method::Lvs { rule: UpdateRule::Hals, tau: Tau::OneOverS },
        Method::Lvs { rule: UpdateRule::Bpp, tau: Tau::OneOverS },
    ]
}

/// Fig. 4 / Tables 4–5: the randomized-method subset rerun with fixed ρ.
pub fn rho_sweep_methods() -> Vec<Method> {
    vec![
        Method::Exact(UpdateRule::Bpp),
        Method::Lai { rule: UpdateRule::Bpp, refine: false },
        Method::Lai { rule: UpdateRule::Bpp, refine: true },
        Method::Lai { rule: UpdateRule::Hals, refine: false },
        Method::Lai { rule: UpdateRule::Hals, refine: true },
        Method::Exact(UpdateRule::Hals),
        Method::Pgncg,
        Method::LaiPgncg { refine: false },
        Method::Comp(UpdateRule::Bpp),
        Method::LaiPgncg { refine: true },
        Method::Comp(UpdateRule::Hals),
    ]
}

/// Table 6: same list as Fig. 1/Table 2 but with static q=2 (no Ada-RRF).
pub fn static_q_options() -> SymNmfOptions {
    let mut o = wos_options();
    o.power = PowerIter::Static(2);
    o
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::randnla::SymOp;

    #[test]
    fn wos_workload_is_symmetric_dense_with_7_topics() {
        let w = wos_workload(70, 1);
        assert_eq!(w.adjacency.rows(), 70);
        assert!(w.adjacency.is_nonneg());
        assert_eq!(w.labels.iter().max().unwrap() + 1, 7);
        for i in 0..70 {
            assert_eq!(w.adjacency.at(i, i), 0.0);
        }
    }

    #[test]
    fn oag_workload_normalized_sparse() {
        let g = oag_workload(400, 2);
        assert!(g.adj.is_symmetric(1e-12));
        assert!(g.adj.nnz() > 400, "should have edges");
        assert!(SymOp::max_value(&g.adj) <= 1.0 + 1e-9);
    }

    #[test]
    fn method_lists_cover_the_paper() {
        assert_eq!(fig1_table2_methods().len(), 11, "Table 2 has 11 rows");
        assert_eq!(fig2_methods().len(), 8);
        assert_eq!(fig3_methods().len(), 3);
        assert_eq!(rho_sweep_methods().len(), 11);
    }
}
