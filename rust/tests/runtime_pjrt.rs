//! Integration: the AOT path end to end — HLO text artifacts (lowered
//! from the JAX model calling Pallas kernels) loaded and executed through
//! PJRT must agree with the native rust kernels to f32 tolerance.
//!
//! Requires `make artifacts`; tests skip (with a loud message) if the
//! artifact directory is missing so plain `cargo test` stays green.

use std::rc::Rc;
use symnmf::coordinator::Method;
use symnmf::linalg::{blas, DenseMat};
use symnmf::nls::{hals, UpdateRule};
use symnmf::randnla::SymOp;
use symnmf::runtime::exec::{hals_sweep_pjrt, lai_products_pjrt, PjrtSymOp};
use symnmf::runtime::registry::Registry;
use symnmf::runtime::PjrtRuntime;
use symnmf::symnmf::{RunControl, SymNmfOptions};
use symnmf::util::rng::Pcg64;

fn runtime() -> Option<Rc<PjrtRuntime>> {
    let dir = Registry::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
        return None;
    }
    Some(Rc::new(PjrtRuntime::new(&dir).expect("PJRT runtime")))
}

fn sym_rand(m: usize, rng: &mut Pcg64) -> DenseMat {
    let mut x = DenseMat::gaussian(m, m, rng);
    x.symmetrize();
    x
}

#[test]
fn products_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let mut rng = Pcg64::seed_from_u64(1);
    let x = sym_rand(64, &mut rng);
    let f = DenseMat::gaussian(64, 8, &mut rng);
    let op = PjrtSymOp::new(x.clone(), rt);
    let (xf, gram) = op.products_pjrt(&f).expect("products_m64_k8 artifact");
    let xf_native = blas::matmul(&x, &f);
    let gram_native = blas::gram(&f);
    let scale = 1.0 + xf_native.fro_norm();
    assert!(
        xf.diff_fro(&xf_native) / scale < 1e-5,
        "X·F mismatch: {}",
        xf.diff_fro(&xf_native)
    );
    assert!(gram.diff_fro(&gram_native) / (1.0 + gram_native.fro_norm()) < 1e-5);
    assert_eq!(op.stats.borrow().pjrt_calls, 1);
}

#[test]
fn symop_apply_dispatches_to_pjrt_and_falls_back() {
    let Some(rt) = runtime() else { return };
    let mut rng = Pcg64::seed_from_u64(2);
    let x = sym_rand(64, &mut rng);
    let op = PjrtSymOp::new(x.clone(), rt);
    // k=8 → artifact exists → PJRT path
    let f8 = DenseMat::gaussian(64, 8, &mut rng);
    let _ = op.apply(&f8);
    assert_eq!(op.stats.borrow().pjrt_calls, 1);
    // k=5 → no artifact → native fallback, result still correct
    let f5 = DenseMat::gaussian(64, 5, &mut rng);
    let y = op.apply(&f5);
    assert_eq!(op.stats.borrow().native_calls, 1);
    assert!(y.diff_fro(&blas::matmul(&x, &f5)) < 1e-12);
}

/// The engine-driven serving shape: a full SymNMF solve over the PJRT
/// operator (artifact dispatch per product, native fallback otherwise),
/// with pause → resume reproducing the uninterrupted run bitwise.
#[test]
fn engine_solve_over_pjrt_operator_pauses_and_resumes() {
    let Some(rt) = runtime() else { return };
    let mut rng = Pcg64::seed_from_u64(9);
    let h = DenseMat::uniform(64, 4, 1.0, &mut rng);
    let mut x = blas::matmul_nt(&h, &h);
    x.symmetrize();
    let op = PjrtSymOp::new(x, rt);
    // k=8 matches the products_m64_k8 artifact; other widths fall back
    let mut opts = SymNmfOptions::new(8).with_seed(3);
    opts.max_iters = 5;
    let method = Method::Exact(UpdateRule::Hals);
    let full = op.solve(method, &opts, &RunControl::unlimited(), None);
    assert!(full.completed());
    assert!(full.result.h.is_nonneg());
    let paused = op.solve(
        method,
        &opts,
        &RunControl::unlimited().with_max_steps(2),
        None,
    );
    let resumed = op.solve(
        method,
        &opts,
        &RunControl::unlimited(),
        Some(&paused.checkpoint),
    );
    assert_eq!(full.result.iters(), resumed.result.iters());
    for (a, b) in full.result.h.data().iter().zip(resumed.result.h.data()) {
        assert_eq!(a.to_bits(), b.to_bits(), "resume must be bitwise on the PJRT path");
    }
}

#[test]
fn lai_products_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let mut rng = Pcg64::seed_from_u64(3);
    let (m, l, k) = (64, 24, 8);
    let u = DenseMat::gaussian(m, l, &mut rng);
    let v = DenseMat::gaussian(m, l, &mut rng);
    let f = DenseMat::gaussian(m, k, &mut rng);
    let (y, g) = lai_products_pjrt(&rt, &u, &v, &f).expect("lai_products artifact");
    let y_native = blas::matmul(&u, &blas::matmul_tn(&v, &f));
    let g_native = blas::gram(&f);
    assert!(y.diff_fro(&y_native) / (1.0 + y_native.fro_norm()) < 1e-5);
    assert!(g.diff_fro(&g_native) / (1.0 + g_native.fro_norm()) < 1e-5);
}

#[test]
fn hals_sweep_artifact_matches_native_sweep() {
    let Some(rt) = runtime() else { return };
    let mut rng = Pcg64::seed_from_u64(4);
    let (m, k) = (64, 8);
    let x = sym_rand(m, &mut rng);
    let mut h = DenseMat::gaussian(m, k, &mut rng);
    h.project_nonneg();
    let mut w = DenseMat::gaussian(m, k, &mut rng);
    w.project_nonneg();
    let alpha = 1.5;
    let xh = blas::matmul(&x, &h);
    let g0 = blas::gram(&h); // WITHOUT α — the artifact applies Eq. 2.6

    let w_pjrt =
        hals_sweep_pjrt(&rt, &xh, &g0, &w, &h, alpha).expect("hals_sweep artifact");

    // native path: Update(G,Y) formulation with G = G0+αI, Y = XH+αH
    // (tested equivalent to Eq. 2.6 in nls::hals unit tests)
    let mut g = g0.clone();
    for i in 0..k {
        *g.at_mut(i, i) += alpha;
    }
    let mut y = xh.clone();
    y.axpy(alpha, &h);
    let mut w_native = w.clone();
    hals::hals_sweep(&g, &y, &mut w_native);

    let scale = 1.0 + w_native.fro_norm();
    assert!(
        w_pjrt.diff_fro(&w_native) / scale < 1e-4,
        "HALS sweep mismatch: {}",
        w_pjrt.diff_fro(&w_native)
    );
    assert!(w_pjrt.is_nonneg());
}

#[test]
fn full_symnmf_through_pjrt_operator() {
    // The L3 coordinator loop running with every X·F through PJRT.
    let Some(rt) = runtime() else { return };
    let mut rng = Pcg64::seed_from_u64(5);
    let h_true = DenseMat::uniform(64, 8, 1.0, &mut rng);
    let mut x = blas::matmul_nt(&h_true, &h_true);
    x.symmetrize();
    let op = PjrtSymOp::new(x, rt);
    let mut opts = symnmf::symnmf::SymNmfOptions::new(8);
    opts.max_iters = 30;
    opts.rule = symnmf::nls::UpdateRule::Hals;
    let res = symnmf::symnmf::anls::symnmf_anls(&op, &opts);
    assert!(
        res.min_residual() < 0.15,
        "residual {} through PJRT path",
        res.min_residual()
    );
    let stats = op.stats.borrow();
    assert!(
        stats.pjrt_calls >= 2 * res.iters(),
        "PJRT calls {} for {} iters — hot path not dispatched?",
        stats.pjrt_calls,
        res.iters()
    );
}
