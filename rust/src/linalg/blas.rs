//! BLAS-like dense kernels, shaped for the paper's workloads.
//!
//! The SymNMF hot path multiplies a large square `X` (m×m) by a skinny
//! factor `F` (m×k, k ≤ ~100). All kernels here use an i-k-j loop order
//! with contiguous row accumulation: for each row `i` of the left operand
//! the output row `out[i, :]` stays hot while rows of the right operand
//! stream through cache. `parallel_for_chunks` splits the `i` range across
//! cores when more than one is available.

use crate::linalg::DenseMat;
use crate::util::threadpool::parallel_for_chunks;
use std::cell::RefCell;

thread_local! {
    /// Reusable staging buffer for the skinny-B transpose of
    /// [`matmul_into`]. Capacity grows to the largest product seen on the
    /// thread and is then reused, so the steady-state hot loop performs
    /// no allocation even when a solve alternates between B shapes
    /// (e.g. the LAI inner product and the metrics X·H product).
    static BT_SCRATCH: RefCell<Vec<f64>> = RefCell::new(Vec::new());
}

/// C = A·B.
pub fn matmul(a: &DenseMat, b: &DenseMat) -> DenseMat {
    let mut c = DenseMat::zeros(a.rows(), b.cols());
    matmul_into(a, b, &mut c);
    c
}

/// C = A·B into a pre-allocated output (hot-path form; no allocation of
/// the output).
///
/// Two regimes (§Perf): for skinny B (n ≤ 64 — the X·F shape that
/// dominates every SymNMF iteration) B is transposed once and each output
/// entry becomes a long contiguous dot product, which the autovectorizer
/// turns into FMA streams; otherwise the row-axpy formulation is used.
pub fn matmul_into(a: &DenseMat, b: &DenseMat, c: &mut DenseMat) {
    let (m, ka) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(ka, kb, "matmul: {:?} x {:?}", a.shape(), b.shape());
    assert_eq!(c.shape(), (m, n));
    if n <= 64 && ka >= 32 {
        // skinny-B path: bt rows are the columns of B, contiguous. The
        // transpose is staged in a thread-local buffer so the per-call
        // allocation the seed paid here is gone (zero-alloc hot loop).
        BT_SCRATCH.with(|cell| {
            let mut bt = cell.borrow_mut();
            if bt.len() != n * ka {
                bt.resize(n * ka, 0.0); // no realloc once capacity covers it
            }
            let bdata = b.data();
            const BLK: usize = 32;
            for ib in (0..ka).step_by(BLK) {
                for jb in (0..n).step_by(BLK) {
                    for i in ib..(ib + BLK).min(ka) {
                        for j in jb..(jb + BLK).min(n) {
                            bt[j * ka + i] = bdata[i * n + j];
                        }
                    }
                }
            }
            let adata = a.data();
            let btdata = &bt[..];
            let cptr = SendPtr(c.data_mut().as_mut_ptr());
            parallel_for_chunks(m, 64, move |lo, hi| {
                let cdata = cptr;
                for i in lo..hi {
                    let arow = &adata[i * ka..(i + 1) * ka];
                    let crow = unsafe {
                        std::slice::from_raw_parts_mut(cdata.0.add(i * n), n)
                    };
                    for (j, cij) in crow.iter_mut().enumerate() {
                        *cij = dot(arow, &btdata[j * ka..(j + 1) * ka]);
                    }
                }
            });
        });
        return;
    }
    let bdata = b.data();
    let adata = a.data();
    let cptr = SendPtr(c.data_mut().as_mut_ptr());
    parallel_for_chunks(m, 64, move |lo, hi| {
        let cdata = cptr;
        for i in lo..hi {
            let arow = &adata[i * ka..(i + 1) * ka];
            // SAFETY: rows [lo, hi) are disjoint across workers.
            let crow = unsafe {
                std::slice::from_raw_parts_mut(cdata.0.add(i * n), n)
            };
            crow.fill(0.0);
            for (kk, &aik) in arow.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                let brow = &bdata[kk * n..(kk + 1) * n];
                axpy(aik, brow, crow);
            }
        }
    });
}

/// y += alpha * x  (contiguous slices).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    // 4-way unrolled; the autovectorizer turns this into mul-add vectors.
    let n = x.len();
    let chunks = n / 4 * 4;
    let (xh, xt) = x.split_at(chunks);
    let (yh, yt) = y.split_at_mut(chunks);
    for (xc, yc) in xh.chunks_exact(4).zip(yh.chunks_exact_mut(4)) {
        yc[0] += alpha * xc[0];
        yc[1] += alpha * xc[1];
        yc[2] += alpha * xc[2];
        yc[3] += alpha * xc[3];
    }
    for (xi, yi) in xt.iter().zip(yt.iter_mut()) {
        *yi += alpha * xi;
    }
}

#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc0 = 0.0;
    let mut acc1 = 0.0;
    let mut acc2 = 0.0;
    let mut acc3 = 0.0;
    let chunks = x.len() / 4 * 4;
    let (xh, xt) = x.split_at(chunks);
    let (yh, yt) = y.split_at(chunks);
    for (xc, yc) in xh.chunks_exact(4).zip(yh.chunks_exact(4)) {
        acc0 += xc[0] * yc[0];
        acc1 += xc[1] * yc[1];
        acc2 += xc[2] * yc[2];
        acc3 += xc[3] * yc[3];
    }
    let mut acc = acc0 + acc1 + acc2 + acc3;
    for (xi, yi) in xt.iter().zip(yt.iter()) {
        acc += xi * yi;
    }
    acc
}

/// C = Aᵀ·B  (A: m×p, B: m×n → C: p×n), streaming both row-major operands
/// once — no explicit transpose is materialized.
pub fn matmul_tn(a: &DenseMat, b: &DenseMat) -> DenseMat {
    let mut c = DenseMat::zeros(a.cols(), b.cols());
    matmul_tn_into(a, b, &mut c);
    c
}

pub fn matmul_tn_into(a: &DenseMat, b: &DenseMat, c: &mut DenseMat) {
    let (m, p) = a.shape();
    let (mb, n) = b.shape();
    assert_eq!(m, mb, "matmul_tn: {:?}ᵀ x {:?}", a.shape(), b.shape());
    assert_eq!(c.shape(), (p, n));
    c.data_mut().fill(0.0);
    let cdata = c.data_mut();
    for i in 0..m {
        let arow = a.row(i);
        let brow = b.row(i);
        for (t, &ait) in arow.iter().enumerate() {
            if ait == 0.0 {
                continue;
            }
            axpy(ait, brow, &mut cdata[t * n..(t + 1) * n]);
        }
    }
}

/// C = A·Bᵀ (A: m×p, B: n×p → C: m×n): each output entry is a dot of two
/// contiguous rows.
pub fn matmul_nt(a: &DenseMat, b: &DenseMat) -> DenseMat {
    let (m, p) = a.shape();
    let (n, pb) = b.shape();
    assert_eq!(p, pb, "matmul_nt: {:?} x {:?}ᵀ", a.shape(), b.shape());
    let mut c = DenseMat::zeros(m, n);
    let cn = c.cols();
    let cptr = SendPtr(c.data_mut().as_mut_ptr());
    parallel_for_chunks(m, 64, move |lo, hi| {
        let cdata = cptr;
        for i in lo..hi {
            let arow = a.row(i);
            let crow = unsafe {
                std::slice::from_raw_parts_mut(cdata.0.add(i * cn), cn)
            };
            for (j, cij) in crow.iter_mut().enumerate() {
                *cij = dot(arow, b.row(j));
            }
        }
    });
    c
}

/// Gram matrix G = FᵀF (k×k), exploiting symmetry (SYRK): only the upper
/// triangle is accumulated, then mirrored.
pub fn gram(f: &DenseMat) -> DenseMat {
    let mut g = DenseMat::zeros(f.cols(), f.cols());
    gram_into(f, &mut g);
    g
}

/// G = FᵀF into a pre-allocated k×k output (hot-path form; the SYRK of
/// every alternating iteration writes into the [`IterWorkspace`] Gram
/// buffer instead of allocating).
///
/// [`IterWorkspace`]: crate::linalg::workspace::IterWorkspace
pub fn gram_into(f: &DenseMat, g: &mut DenseMat) {
    let (m, k) = f.shape();
    assert_eq!(g.shape(), (k, k), "gram_into: output must be {k}x{k}");
    {
        let gd = g.data_mut();
        gd.fill(0.0);
        for i in 0..m {
            let row = f.row(i);
            for t in 0..k {
                let v = row[t];
                if v == 0.0 {
                    continue;
                }
                let grow = &mut gd[t * k..(t + 1) * k];
                for u in t..k {
                    grow[u] += v * row[u];
                }
            }
        }
    }
    for t in 0..k {
        for u in (t + 1)..k {
            let v = g.at(t, u);
            g.set(u, t, v);
        }
    }
}

/// out = X·F where X is a large symmetric square matrix. Currently an
/// alias of `matmul_into`; kept distinct so a symmetry-exploiting or
/// PJRT-dispatched kernel can slot in without touching call sites.
pub fn symm_tall_into(x: &DenseMat, f: &DenseMat, out: &mut DenseMat) {
    matmul_into(x, f, out);
}

/// Raw mutable pointer wrapper so disjoint row ranges can be written from
/// scoped worker threads.
#[derive(Clone, Copy)]
struct SendPtr(*mut f64);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{dim, forall};
    use crate::util::rng::Pcg64;

    fn naive_matmul(a: &DenseMat, b: &DenseMat) -> DenseMat {
        let (m, k) = a.shape();
        let n = b.cols();
        DenseMat::from_fn(m, n, |i, j| {
            (0..k).map(|t| a.at(i, t) * b.at(t, j)).sum()
        })
    }

    #[test]
    fn matmul_matches_naive_property() {
        forall(
            20,
            100,
            |rng| {
                let m = dim(rng, 1, 30);
                let k = dim(rng, 1, 30);
                let n = dim(rng, 1, 30);
                (DenseMat::gaussian(m, k, rng), DenseMat::gaussian(k, n, rng))
            },
            |(a, b)| {
                let got = matmul(a, b);
                let want = naive_matmul(a, b);
                let err = got.diff_fro(&want);
                if err < 1e-10 * (1.0 + want.fro_norm()) {
                    Ok(())
                } else {
                    Err(format!("err={err}"))
                }
            },
        );
    }

    #[test]
    fn tn_and_nt_match_explicit_transpose() {
        forall(
            15,
            200,
            |rng| {
                let m = dim(rng, 1, 25);
                let p = dim(rng, 1, 25);
                let n = dim(rng, 1, 25);
                (DenseMat::gaussian(m, p, rng), DenseMat::gaussian(m, n, rng),
                 DenseMat::gaussian(n, p, rng))
            },
            |(a, b, c)| {
                let tn = matmul_tn(a, b);
                let tn_want = naive_matmul(&a.transpose(), b);
                if tn.diff_fro(&tn_want) > 1e-10 * (1.0 + tn_want.fro_norm()) {
                    return Err("tn mismatch".into());
                }
                let nt = matmul_nt(a, c);
                let nt_want = naive_matmul(a, &c.transpose());
                if nt.diff_fro(&nt_want) > 1e-10 * (1.0 + nt_want.fro_norm()) {
                    return Err("nt mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn gram_matches_tn_and_is_symmetric_psd() {
        let mut rng = Pcg64::seed_from_u64(5);
        let f = DenseMat::gaussian(40, 9, &mut rng);
        let g = gram(&f);
        let want = matmul_tn(&f, &f);
        assert!(g.diff_fro(&want) < 1e-10);
        for i in 0..9 {
            assert!(g.at(i, i) >= 0.0);
            for j in 0..9 {
                assert!((g.at(i, j) - g.at(j, i)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg64::seed_from_u64(6);
        let a = DenseMat::gaussian(8, 8, &mut rng);
        let i = DenseMat::eye(8);
        assert!(matmul(&a, &i).diff_fro(&a) < 1e-14);
        assert!(matmul(&i, &a).diff_fro(&a) < 1e-14);
    }

    #[test]
    fn dot_and_axpy() {
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let mut y = vec![1.0; 5];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![3.0, 5.0, 7.0, 9.0, 11.0]);
        assert_eq!(dot(&x, &x), 55.0);
    }
}
