//! XLA/PJRT backend facade.
//!
//! The real `xla` crate (PJRT CPU client, HLO-text parsing, compiled
//! executables) is an external dependency this build intentionally does
//! NOT declare: the crate is zero-dependency so the tier-1 gate
//! (`cargo build --release && cargo test -q`) runs hermetically with no
//! network access. This module mirrors the slice of the `xla` API the
//! runtime uses:
//!
//! * [`Literal`] — the host-side tensor container — is **fully
//!   functional**, so the f64⇄f32 conversion helpers in
//!   [`crate::runtime::pjrt`] (and their tests) work without the backend;
//! * client construction ([`PjRtClient::cpu`]) returns an "unavailable"
//!   error, so every caller falls through to the native blocked kernels
//!   (the same transparent-fallback path used when no artifact matches a
//!   shape).
//!
//! Wiring the real backend back in is a two-line swap: declare the `xla`
//! crate in `Cargo.toml` and replace this module's body with
//! `pub use ::xla::*;`.

use crate::util::error::{Error, Result};

const UNAVAILABLE: &str = "XLA/PJRT backend not compiled into this build \
                           (zero-dependency build); native kernels are used instead";

/// Host-side tensor literal: f32 data plus dimensions.
#[derive(Clone, Debug)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a flat f32 slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar(v: f32) -> Literal {
        Literal { data: vec![v], dims: Vec::new() }
    }

    /// Reinterpret under new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error::msg(format!(
                "reshape {:?} -> {dims:?}: element count mismatch ({} elements)",
                self.dims,
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Flat element read-out.
    pub fn to_vec(&self) -> Result<Vec<f32>> {
        Ok(self.data.clone())
    }

    /// Destructure a tuple literal. Only executables produce tuples, and
    /// the stub client never executes, so this is unreachable here.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::msg(UNAVAILABLE))
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// PJRT client handle. The stub cannot be constructed: [`PjRtClient::cpu`]
/// always errors, which routes every runtime consumer to native kernels.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::msg(UNAVAILABLE))
    }

    pub fn platform_name(&self) -> String {
        "unavailable".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::msg(UNAVAILABLE))
    }
}

/// Parsed HLO module. Parsing requires the backend.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::msg(UNAVAILABLE))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::msg(UNAVAILABLE))
    }
}

/// A device buffer returned by execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::msg(UNAVAILABLE))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_container_is_functional() {
        let lit = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(lit.dims(), &[6]);
        let shaped = lit.reshape(&[2, 3]).unwrap();
        assert_eq!(shaped.dims(), &[2, 3]);
        assert_eq!(shaped.to_vec().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(lit.reshape(&[4, 2]).is_err(), "element count must match");
        let s = Literal::scalar(2.5);
        assert_eq!(s.dims().len(), 0);
        assert_eq!(s.to_vec().unwrap(), vec![2.5]);
    }

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().err().expect("stub client must not construct");
        assert!(e.to_string().contains("not compiled"), "{e}");
    }
}
