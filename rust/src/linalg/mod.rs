//! Dense numerical linear algebra substrate (f64, row-major).
//!
//! Everything the paper's algorithms need is implemented here from
//! scratch: blocked matmul/Gram kernels ([`blas`]), Cholesky factorization
//! and triangular solves ([`chol`]), CholeskyQR + Householder QR and row
//! leverage scores ([`qr`]), a cyclic-Jacobi symmetric eigensolver
//! ([`eig`]) used by Apx-EVD (paper Alg. Apx-EVD line 5), and the
//! zero-allocation per-iteration buffer workspace ([`workspace`]) behind
//! the `apply_into` kernel dispatch protocol, and the packed-triangular
//! symmetric storage ([`packed`]) that halves the resident footprint of
//! the dense data matrix.

pub mod blas;
pub mod chol;
pub mod dense;
pub mod eig;
pub mod packed;
pub mod qr;
pub mod workspace;

pub use dense::DenseMat;
pub use packed::SymPacked;
pub use workspace::{IterWorkspace, PanelBuf, UpdateScratch};
