//! BLAS-like dense kernels, shaped for the paper's workloads.
//!
//! The SymNMF hot path multiplies a large square symmetric `X` (m×m) by a
//! skinny factor `F` (m×k, k ≤ ~100). The kernels are organized around
//! two blocking ideas:
//!
//! **Register blocking (the NT microkernel).** Products whose right
//! operand is accessed row-contiguously transposed — the skinny-B path of
//! [`matmul_into`] and all of [`matmul_nt_into`] — run on a shared 2×4
//! register tile: two left rows × four right rows are multiplied in one
//! pass with eight scalar accumulators, so every loaded element of the
//! right panel feeds two FMAs and every left element four. All streams
//! are contiguous in the reduction index, which the autovectorizer turns
//! into FMA vectors; the j-panel width of 4 keeps the accumulators in
//! registers. Skinny B is transposed once per call into a thread-local
//! staging buffer ([`BT_SCRATCH`]), so the hot loop allocates nothing.
//!
//! **Cache blocking with symmetry (the SYMM kernel).** [`symm_tall_into`]
//! partitions symmetric X into `SYMM_BLOCK`-sized row/column blocks and
//! walks only the upper-triangle block pairs: each off-diagonal block
//! X[I,J] is read once and applied to both output panels
//! (out[I] += X[I,J]·F[J] and out[J] += X[I,J]ᵀ·F[I]), roughly halving
//! X memory traffic relative to the plain GEMM. Workers accumulate into
//! private m×k buffers (round-robin over block pairs) which are reduced
//! in fixed worker order, so the result is deterministic for a given
//! thread count.
//!
//! `parallel_for_chunks` splits row ranges across cores when more than
//! one is available; partitioning is balanced and deterministic (see
//! [`crate::util::threadpool`]).

use crate::linalg::DenseMat;
use crate::util::threadpool::{num_threads, parallel_for_chunks, SendPtr};
use std::cell::RefCell;

thread_local! {
    /// Reusable staging buffer for the skinny-B transpose of
    /// [`matmul_into`]. Capacity grows to the largest product seen on the
    /// thread and is then reused, so the steady-state hot loop performs
    /// no allocation even when a solve alternates between B shapes
    /// (e.g. the LAI inner product and the metrics X·H product).
    static BT_SCRATCH: RefCell<Vec<f64>> = RefCell::new(Vec::new());

    /// Per-call accumulator pool for the multi-worker path of
    /// [`symm_tall_into`]: `nt` private m×k buffers, reused across calls
    /// on the same thread (nested kernel calls from batched trials each
    /// see their own pool).
    static SYMM_ACC: RefCell<Vec<f64>> = RefCell::new(Vec::new());
}

/// C = A·B.
pub fn matmul(a: &DenseMat, b: &DenseMat) -> DenseMat {
    let mut c = DenseMat::zeros(a.rows(), b.cols());
    matmul_into(a, b, &mut c);
    c
}

/// C = A·B into a pre-allocated output (hot-path form; no allocation of
/// the output).
///
/// Two regimes (§Perf): for skinny B (n ≤ 64 — the X·F shape that
/// dominates every SymNMF iteration) B is transposed once into the
/// thread-local staging buffer and the product runs on the 2×4 register
/// tile of [`nt_rows`]; otherwise the row-axpy formulation is used.
pub fn matmul_into(a: &DenseMat, b: &DenseMat, c: &mut DenseMat) {
    let (m, ka) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(ka, kb, "matmul: {:?} x {:?}", a.shape(), b.shape());
    assert_eq!(c.shape(), (m, n));
    if n <= 64 && ka >= 32 {
        // skinny-B path: bt rows are the columns of B, contiguous. The
        // transpose is staged in a thread-local buffer so the per-call
        // allocation the seed paid here is gone (zero-alloc hot loop).
        BT_SCRATCH.with(|cell| {
            let mut bt = cell.borrow_mut();
            if bt.len() != n * ka {
                bt.resize(n * ka, 0.0); // no realloc once capacity covers it
            }
            let bdata = b.data();
            const BLK: usize = 32;
            for ib in (0..ka).step_by(BLK) {
                for jb in (0..n).step_by(BLK) {
                    for i in ib..(ib + BLK).min(ka) {
                        for j in jb..(jb + BLK).min(n) {
                            bt[j * ka + i] = bdata[i * n + j];
                        }
                    }
                }
            }
            let adata = a.data();
            let btdata = &bt[..];
            let cptr = SendPtr(c.data_mut().as_mut_ptr());
            parallel_for_chunks(m, 64, move |lo, hi| {
                nt_rows(adata, ka, btdata, n, lo, hi, cptr);
            });
        });
        return;
    }
    let bdata = b.data();
    let adata = a.data();
    let cptr = SendPtr(c.data_mut().as_mut_ptr());
    parallel_for_chunks(m, 64, move |lo, hi| {
        let cdata = cptr;
        for i in lo..hi {
            let arow = &adata[i * ka..(i + 1) * ka];
            // SAFETY: rows [lo, hi) are disjoint across workers.
            let crow = unsafe {
                std::slice::from_raw_parts_mut(cdata.0.add(i * n), n)
            };
            crow.fill(0.0);
            for (kk, &aik) in arow.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                let brow = &bdata[kk * n..(kk + 1) * n];
                axpy(aik, brow, crow);
            }
        }
    });
}

/// The register-blocked NT microkernel: writes C rows [lo, hi) of
/// C = A·BTᵀ, where `a` is m×p row-major and `bt` is n×p row-major (the
/// TRANSPOSE of the logical right operand, so both reduction streams are
/// contiguous). Rows are processed in pairs against 4-column panels of
/// the output: 8 accumulators, 6 loads and 8 FMAs per reduction step.
fn nt_rows(a: &[f64], p: usize, bt: &[f64], n: usize, lo: usize, hi: usize, cptr: SendPtr) {
    let mut i = lo;
    while i + 2 <= hi {
        let a0 = &a[i * p..(i + 1) * p];
        let a1 = &a[(i + 1) * p..(i + 2) * p];
        // SAFETY: rows [lo, hi) are disjoint across workers.
        let c0 = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(i * n), n) };
        let c1 = unsafe { std::slice::from_raw_parts_mut(cptr.0.add((i + 1) * n), n) };
        nt_row_pair(a0, a1, p, bt, n, c0, c1);
        i += 2;
    }
    if i < hi {
        let a0 = &a[i * p..(i + 1) * p];
        // SAFETY: as above.
        let c0 = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(i * n), n) };
        nt_row_single(a0, p, bt, n, c0);
    }
}

/// 2×4 tile: two A rows against panels of four BT rows.
#[inline]
#[allow(clippy::too_many_arguments)]
fn nt_row_pair(
    a0: &[f64],
    a1: &[f64],
    p: usize,
    bt: &[f64],
    n: usize,
    c0: &mut [f64],
    c1: &mut [f64],
) {
    let mut j = 0;
    while j + 4 <= n {
        let b0 = &bt[j * p..(j + 1) * p];
        let b1 = &bt[(j + 1) * p..(j + 2) * p];
        let b2 = &bt[(j + 2) * p..(j + 3) * p];
        let b3 = &bt[(j + 3) * p..(j + 4) * p];
        let (mut s00, mut s01, mut s02, mut s03) = (0.0f64, 0.0, 0.0, 0.0);
        let (mut s10, mut s11, mut s12, mut s13) = (0.0f64, 0.0, 0.0, 0.0);
        for t in 0..p {
            let x0 = a0[t];
            let x1 = a1[t];
            s00 += x0 * b0[t];
            s01 += x0 * b1[t];
            s02 += x0 * b2[t];
            s03 += x0 * b3[t];
            s10 += x1 * b0[t];
            s11 += x1 * b1[t];
            s12 += x1 * b2[t];
            s13 += x1 * b3[t];
        }
        c0[j] = s00;
        c0[j + 1] = s01;
        c0[j + 2] = s02;
        c0[j + 3] = s03;
        c1[j] = s10;
        c1[j + 1] = s11;
        c1[j + 2] = s12;
        c1[j + 3] = s13;
        j += 4;
    }
    while j < n {
        let b = &bt[j * p..(j + 1) * p];
        c0[j] = dot(a0, b);
        c1[j] = dot(a1, b);
        j += 1;
    }
}

/// 1×4 tail tile for an odd final row.
fn nt_row_single(a0: &[f64], p: usize, bt: &[f64], n: usize, c0: &mut [f64]) {
    let mut j = 0;
    while j + 4 <= n {
        let b0 = &bt[j * p..(j + 1) * p];
        let b1 = &bt[(j + 1) * p..(j + 2) * p];
        let b2 = &bt[(j + 2) * p..(j + 3) * p];
        let b3 = &bt[(j + 3) * p..(j + 4) * p];
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0, 0.0, 0.0);
        for t in 0..p {
            let x = a0[t];
            s0 += x * b0[t];
            s1 += x * b1[t];
            s2 += x * b2[t];
            s3 += x * b3[t];
        }
        c0[j] = s0;
        c0[j + 1] = s1;
        c0[j + 2] = s2;
        c0[j + 3] = s3;
        j += 4;
    }
    while j < n {
        c0[j] = dot(a0, &bt[j * p..(j + 1) * p]);
        j += 1;
    }
}

/// y += alpha * x  (contiguous slices).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    // 4-way unrolled; the autovectorizer turns this into mul-add vectors.
    let n = x.len();
    let chunks = n / 4 * 4;
    let (xh, xt) = x.split_at(chunks);
    let (yh, yt) = y.split_at_mut(chunks);
    for (xc, yc) in xh.chunks_exact(4).zip(yh.chunks_exact_mut(4)) {
        yc[0] += alpha * xc[0];
        yc[1] += alpha * xc[1];
        yc[2] += alpha * xc[2];
        yc[3] += alpha * xc[3];
    }
    for (xi, yi) in xt.iter().zip(yt.iter_mut()) {
        *yi += alpha * xi;
    }
}

#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc0 = 0.0;
    let mut acc1 = 0.0;
    let mut acc2 = 0.0;
    let mut acc3 = 0.0;
    let chunks = x.len() / 4 * 4;
    let (xh, xt) = x.split_at(chunks);
    let (yh, yt) = y.split_at(chunks);
    for (xc, yc) in xh.chunks_exact(4).zip(yh.chunks_exact(4)) {
        acc0 += xc[0] * yc[0];
        acc1 += xc[1] * yc[1];
        acc2 += xc[2] * yc[2];
        acc3 += xc[3] * yc[3];
    }
    let mut acc = acc0 + acc1 + acc2 + acc3;
    for (xi, yi) in xt.iter().zip(yt.iter()) {
        acc += xi * yi;
    }
    acc
}

/// C = Aᵀ·B  (A: m×p, B: m×n → C: p×n), streaming both row-major operands
/// once — no explicit transpose is materialized.
pub fn matmul_tn(a: &DenseMat, b: &DenseMat) -> DenseMat {
    let mut c = DenseMat::zeros(a.cols(), b.cols());
    matmul_tn_into(a, b, &mut c);
    c
}

pub fn matmul_tn_into(a: &DenseMat, b: &DenseMat, c: &mut DenseMat) {
    let (m, p) = a.shape();
    let (mb, n) = b.shape();
    assert_eq!(m, mb, "matmul_tn: {:?}ᵀ x {:?}", a.shape(), b.shape());
    assert_eq!(c.shape(), (p, n));
    c.data_mut().fill(0.0);
    let cdata = c.data_mut();
    for i in 0..m {
        let arow = a.row(i);
        let brow = b.row(i);
        for (t, &ait) in arow.iter().enumerate() {
            if ait == 0.0 {
                continue;
            }
            axpy(ait, brow, &mut cdata[t * n..(t + 1) * n]);
        }
    }
}

/// C = A·Bᵀ (A: m×p, B: n×p → C: m×n): both operands are row-contiguous
/// in the reduction index, so this is the NT microkernel applied
/// directly — no staging transpose at all.
pub fn matmul_nt(a: &DenseMat, b: &DenseMat) -> DenseMat {
    let mut c = DenseMat::zeros(a.rows(), b.rows());
    matmul_nt_into(a, b, &mut c);
    c
}

/// C = A·Bᵀ into a pre-allocated output (hot-path form; no allocation).
pub fn matmul_nt_into(a: &DenseMat, b: &DenseMat, c: &mut DenseMat) {
    let (m, p) = a.shape();
    let (n, pb) = b.shape();
    assert_eq!(p, pb, "matmul_nt: {:?} x {:?}ᵀ", a.shape(), b.shape());
    assert_eq!(c.shape(), (m, n));
    let adata = a.data();
    let btdata = b.data();
    let cptr = SendPtr(c.data_mut().as_mut_ptr());
    parallel_for_chunks(m, 64, move |lo, hi| {
        nt_rows(adata, p, btdata, n, lo, hi, cptr);
    });
}

/// Gram matrix G = FᵀF (k×k), exploiting symmetry (SYRK): only the upper
/// triangle is accumulated, then mirrored.
pub fn gram(f: &DenseMat) -> DenseMat {
    let mut g = DenseMat::zeros(f.cols(), f.cols());
    gram_into(f, &mut g);
    g
}

/// G = FᵀF into a pre-allocated k×k output (hot-path form; the SYRK of
/// every alternating iteration writes into the [`IterWorkspace`] Gram
/// buffer instead of allocating).
///
/// [`IterWorkspace`]: crate::linalg::workspace::IterWorkspace
pub fn gram_into(f: &DenseMat, g: &mut DenseMat) {
    let (m, k) = f.shape();
    assert_eq!(g.shape(), (k, k), "gram_into: output must be {k}x{k}");
    {
        let gd = g.data_mut();
        gd.fill(0.0);
        for i in 0..m {
            let row = f.row(i);
            for t in 0..k {
                let v = row[t];
                if v == 0.0 {
                    continue;
                }
                let grow = &mut gd[t * k..(t + 1) * k];
                for u in t..k {
                    grow[u] += v * row[u];
                }
            }
        }
    }
    for t in 0..k {
        for u in (t + 1)..k {
            let v = g.at(t, u);
            g.set(u, t, v);
        }
    }
}

/// Row/column block size of the symmetric kernel. A block pair touches
/// one SYMM_BLOCK² panel of X (128 KiB) plus two SYMM_BLOCK×k panels each
/// of F and the accumulator (64 KiB at k = 32) — comfortably L2-resident
/// while X itself streams through once.
const SYMM_BLOCK: usize = 128;

/// out = X·F where X is a large **symmetric** square matrix. Only blocks
/// on or above the block diagonal are read — strictly-lower off-diagonal
/// blocks are never touched, halving X traffic (diagonal blocks are read
/// in full, so X must still be stored as a complete square array).
/// Dispatches to the cache-blocked kernel ([`symm_tall_into_blocked`])
/// for the shapes where the saved traffic pays off, and to the generic
/// [`matmul_into`] otherwise: small X, F wide enough that the panel
/// working set would spill L2, or a multi-worker accumulator-pool
/// overhead (≈ 2·nt·m·k element ops to zero + reduce) that would exceed
/// the ≈ m²/2 element reads it saves.
pub fn symm_tall_into(x: &DenseMat, f: &DenseMat, out: &mut DenseMat) {
    let m = x.rows();
    let k = f.cols();
    let nt = num_threads();
    if k > 64 || m < 2 * SYMM_BLOCK || (nt > 1 && m < 4 * nt * k) {
        matmul_into(x, f, out);
        return;
    }
    symm_tall_into_blocked(x, f, out, SYMM_BLOCK);
}

/// The blocked symmetric kernel with an explicit block size (exposed so
/// tests can exercise multi-block tiling on small shapes and benchmarks
/// can sweep block sizes). X must be symmetric: only blocks on or above
/// the block diagonal are read (diagonal blocks in full, including their
/// strictly-lower entries); each off-diagonal block is applied to both
/// output panels. With more than one worker thread, block pairs are dealt
/// round-robin to workers accumulating into private buffers from the
/// thread-local pool, then reduced in fixed worker order — deterministic
/// for a given thread count.
pub fn symm_tall_into_blocked(x: &DenseMat, f: &DenseMat, out: &mut DenseMat, block: usize) {
    let (m, mc) = x.shape();
    assert_eq!(m, mc, "symm_tall_into: X must be square, got {:?}", x.shape());
    let (mf, k) = f.shape();
    assert_eq!(m, mf, "symm_tall_into: X is {m}x{m} but F has {mf} rows");
    assert_eq!(out.shape(), (m, k), "symm_tall_into: output must be {m}x{k}");
    assert!(block >= 1, "symm_tall_into: block size must be positive");
    if m == 0 || k == 0 {
        out.data_mut().fill(0.0);
        return;
    }
    let nb = m.div_ceil(block);
    let npairs = nb * (nb + 1) / 2;
    let nt = num_threads().min(npairs).max(1);
    let xd = x.data();
    let fd = f.data();
    if nt == 1 {
        let od = out.data_mut();
        od.fill(0.0);
        for ib in 0..nb {
            for jb in ib..nb {
                symm_block_pair(xd, fd, m, k, block, ib, jb, od);
            }
        }
        return;
    }
    SYMM_ACC.with(|cell| {
        let mut pool_ref = cell.borrow_mut();
        let need = nt * m * k;
        if pool_ref.len() < need {
            pool_ref.resize(need, 0.0);
        }
        let pool: &mut [f64] = &mut pool_ref[..need];
        pool.fill(0.0);
        std::thread::scope(|s| {
            for (t, acc) in pool.chunks_mut(m * k).enumerate() {
                s.spawn(move || {
                    let mut p = 0usize;
                    for ib in 0..nb {
                        for jb in ib..nb {
                            if p % nt == t {
                                symm_block_pair(xd, fd, m, k, block, ib, jb, acc);
                            }
                            p += 1;
                        }
                    }
                });
            }
        });
        // Deterministic reduction: out[row] = Σ_t acc_t[row], in worker
        // order, row-parallel.
        let pool_s: &[f64] = pool;
        let optr = SendPtr(out.data_mut().as_mut_ptr());
        parallel_for_chunks(m, 256, move |lo, hi| {
            // SAFETY: disjoint row ranges per worker.
            let od = unsafe {
                std::slice::from_raw_parts_mut(optr.0.add(lo * k), (hi - lo) * k)
            };
            od.copy_from_slice(&pool_s[lo * k..hi * k]);
            for t in 1..nt {
                let base = t * m * k;
                let part = &pool_s[base + lo * k..base + hi * k];
                for (o, &v) in od.iter_mut().zip(part) {
                    *o += v;
                }
            }
        });
    });
}

/// Apply the (ib, jb) upper-triangle block pair of symmetric X to F,
/// accumulating into `acc` (m×k row-major). For ib == jb this is the
/// plain diagonal-block product; for ib < jb the block X[I,J] is read
/// once and applied to both output panels:
/// acc[I] += X[I,J]·F[J] and acc[J] += X[I,J]ᵀ·F[I].
#[allow(clippy::too_many_arguments)]
fn symm_block_pair(
    xd: &[f64],
    fd: &[f64],
    m: usize,
    k: usize,
    block: usize,
    ib: usize,
    jb: usize,
    acc: &mut [f64],
) {
    let i0 = ib * block;
    let i1 = (i0 + block).min(m);
    let j0 = jb * block;
    let j1 = (j0 + block).min(m);
    if ib == jb {
        for i in i0..i1 {
            let xrow = &xd[i * m + j0..i * m + j1];
            let acci = &mut acc[i * k..(i + 1) * k];
            for (jj, &v) in xrow.iter().enumerate() {
                if v != 0.0 {
                    let j = j0 + jj;
                    axpy(v, &fd[j * k..(j + 1) * k], acci);
                }
            }
        }
        return;
    }
    // Off-diagonal pair: i1 <= j0 by construction, so the I-panel and
    // J-panel of the accumulator can be split and written simultaneously.
    let (acc_i, acc_j) = acc.split_at_mut(j0 * k);
    for i in i0..i1 {
        let xrow = &xd[i * m + j0..i * m + j1];
        let fi = &fd[i * k..(i + 1) * k];
        let acci = &mut acc_i[i * k..(i + 1) * k];
        for (jj, &v) in xrow.iter().enumerate() {
            if v != 0.0 {
                let j = j0 + jj;
                axpy(v, &fd[j * k..(j + 1) * k], acci);
                axpy(v, fi, &mut acc_j[(j - j0) * k..(j - j0 + 1) * k]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{dim, forall};
    use crate::util::rng::Pcg64;

    fn naive_matmul(a: &DenseMat, b: &DenseMat) -> DenseMat {
        let (m, k) = a.shape();
        let n = b.cols();
        DenseMat::from_fn(m, n, |i, j| {
            (0..k).map(|t| a.at(i, t) * b.at(t, j)).sum()
        })
    }

    #[test]
    fn matmul_matches_naive_property() {
        forall(
            20,
            100,
            |rng| {
                let m = dim(rng, 1, 30);
                let k = dim(rng, 1, 30);
                let n = dim(rng, 1, 30);
                (DenseMat::gaussian(m, k, rng), DenseMat::gaussian(k, n, rng))
            },
            |(a, b)| {
                let got = matmul(a, b);
                let want = naive_matmul(a, b);
                let err = got.diff_fro(&want);
                if err < 1e-10 * (1.0 + want.fro_norm()) {
                    Ok(())
                } else {
                    Err(format!("err={err}"))
                }
            },
        );
    }

    /// The skinny-B register-tiled path must agree with the naive product
    /// across non-multiple-of-tile shapes (odd row counts, j-panel tails).
    #[test]
    fn skinny_register_tile_matches_naive() {
        let mut rng = Pcg64::seed_from_u64(11);
        for m in [1usize, 3, 31, 33, 65] {
            for n in [1usize, 3, 31, 33, 64] {
                // ka >= 32 triggers the transposed register-tile path
                let ka = 37;
                let a = DenseMat::gaussian(m, ka, &mut rng);
                let b = DenseMat::gaussian(ka, n, &mut rng);
                let got = matmul(&a, &b);
                let want = naive_matmul(&a, &b);
                let err = got.diff_fro(&want);
                assert!(
                    err < 1e-12 * (1.0 + want.fro_norm()),
                    "m={m} n={n}: err={err}"
                );
            }
        }
    }

    #[test]
    fn tn_and_nt_match_explicit_transpose() {
        forall(
            15,
            200,
            |rng| {
                let m = dim(rng, 1, 25);
                let p = dim(rng, 1, 25);
                let n = dim(rng, 1, 25);
                (DenseMat::gaussian(m, p, rng), DenseMat::gaussian(m, n, rng),
                 DenseMat::gaussian(n, p, rng))
            },
            |(a, b, c)| {
                let tn = matmul_tn(a, b);
                let tn_want = naive_matmul(&a.transpose(), b);
                if tn.diff_fro(&tn_want) > 1e-10 * (1.0 + tn_want.fro_norm()) {
                    return Err("tn mismatch".into());
                }
                let nt = matmul_nt(a, c);
                let nt_want = naive_matmul(a, &c.transpose());
                if nt.diff_fro(&nt_want) > 1e-10 * (1.0 + nt_want.fro_norm()) {
                    return Err("nt mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn nt_into_matches_allocating_form() {
        let mut rng = Pcg64::seed_from_u64(12);
        for (m, p, n) in [(1, 5, 1), (3, 9, 7), (33, 31, 65), (65, 4, 33)] {
            let a = DenseMat::gaussian(m, p, &mut rng);
            let b = DenseMat::gaussian(n, p, &mut rng);
            let want = matmul_nt(&a, &b);
            let mut c = DenseMat::zeros(m, n);
            c.fill(99.0); // stale data must be overwritten
            matmul_nt_into(&a, &b, &mut c);
            assert!(c.diff_fro(&want) == 0.0, "({m},{p},{n})");
        }
    }

    #[test]
    fn gram_matches_tn_and_is_symmetric_psd() {
        let mut rng = Pcg64::seed_from_u64(5);
        let f = DenseMat::gaussian(40, 9, &mut rng);
        let g = gram(&f);
        let want = matmul_tn(&f, &f);
        assert!(g.diff_fro(&want) < 1e-10);
        for i in 0..9 {
            assert!(g.at(i, i) >= 0.0);
            for j in 0..9 {
                assert!((g.at(i, j) - g.at(j, i)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg64::seed_from_u64(6);
        let a = DenseMat::gaussian(8, 8, &mut rng);
        let i = DenseMat::eye(8);
        assert!(matmul(&a, &i).diff_fro(&a) < 1e-14);
        assert!(matmul(&i, &a).diff_fro(&a) < 1e-14);
    }

    #[test]
    fn dot_and_axpy() {
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let mut y = vec![1.0; 5];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![3.0, 5.0, 7.0, 9.0, 11.0]);
        assert_eq!(dot(&x, &x), 55.0);
    }

    fn random_symmetric(m: usize, rng: &mut Pcg64) -> DenseMat {
        let mut x = DenseMat::gaussian(m, m, rng);
        x.symmetrize();
        x
    }

    /// Blocked SYMM vs the generic GEMM at 1e-12, across
    /// non-multiple-of-block shapes and block sizes (including blocks
    /// larger than the matrix and single-row matrices).
    #[test]
    fn blocked_symm_matches_gemm_across_shapes() {
        let mut rng = Pcg64::seed_from_u64(13);
        for m in [1usize, 3, 31, 33, 65] {
            let x = random_symmetric(m, &mut rng);
            for k in [1usize, 3, 31, 33, 65] {
                let f = DenseMat::gaussian(m, k, &mut rng);
                let want = naive_matmul(&x, &f);
                for block in [4usize, 8, 32, 256] {
                    let mut out = DenseMat::zeros(m, k);
                    out.fill(-3.0); // stale data must be overwritten
                    symm_tall_into_blocked(&x, &f, &mut out, block);
                    let err = out.diff_fro(&want);
                    assert!(
                        err < 1e-12 * (1.0 + want.fro_norm()),
                        "m={m} k={k} block={block}: err={err}"
                    );
                }
            }
        }
    }

    /// The public dispatcher must agree with the generic GEMM on a shape
    /// large enough to take the blocked path — sized from num_threads()
    /// so the dispatch predicate (m ≥ 4·nt·k) selects the blocked kernel
    /// on any machine, not just small-core-count ones.
    #[test]
    fn symm_dispatch_matches_gemm_on_blocked_shape() {
        let mut rng = Pcg64::seed_from_u64(14);
        let k = 9;
        // + 37 keeps m off the block-size multiples
        let m = (2 * SYMM_BLOCK).max(4 * num_threads() * k) + 37;
        let x = random_symmetric(m, &mut rng);
        let f = DenseMat::gaussian(m, k, &mut rng);
        let mut got = DenseMat::zeros(m, k);
        symm_tall_into(&x, &f, &mut got);
        let want = matmul(&x, &f);
        let err = got.diff_fro(&want);
        assert!(err < 1e-12 * (1.0 + want.fro_norm()), "err={err}");
    }

    /// Same input, repeated calls → bitwise-identical output (the batched
    /// multi-seed driver relies on kernel determinism). Calls the blocked
    /// kernel directly with a small block so the multi-worker
    /// accumulator-pool path runs regardless of the dispatch heuristic.
    #[test]
    fn blocked_symm_is_deterministic() {
        let mut rng = Pcg64::seed_from_u64(15);
        let m = 300;
        let x = random_symmetric(m, &mut rng);
        let f = DenseMat::gaussian(m, 8, &mut rng);
        let mut a = DenseMat::zeros(m, 8);
        let mut b = DenseMat::zeros(m, 8);
        symm_tall_into_blocked(&x, &f, &mut a, 64);
        symm_tall_into_blocked(&x, &f, &mut b, 64);
        for (va, vb) in a.data().iter().zip(b.data()) {
            assert_eq!(va.to_bits(), vb.to_bits());
        }
    }
}
