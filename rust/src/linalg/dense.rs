//! Row-major dense f64 matrix.
//!
//! Row-major layout is chosen deliberately: the NMF factors W, H are tall
//! (m×k) and every per-row operation in the paper — BPP's per-row QPs
//! (App. E), leverage-score row norms (Eq. 2.10), sampled-row gathers
//! (Eq. 2.11) — touches contiguous memory.

use crate::linalg::simd;
use crate::util::rng::Pcg64;

/// Dense row-major matrix of f64.
#[derive(Clone, PartialEq)]
pub struct DenseMat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl std::fmt::Debug for DenseMat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "DenseMat({}x{})", self.rows, self.cols)?;
        if self.rows * self.cols <= 64 {
            for i in 0..self.rows {
                write!(f, "\n  {:?}", self.row(i))?;
            }
        }
        Ok(())
    }
}

impl DenseMat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        DenseMat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        DenseMat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        DenseMat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        Self::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// i.i.d. standard Gaussian entries (the Ω of Alg. RRF line 3).
    pub fn gaussian(rows: usize, cols: usize, rng: &mut Pcg64) -> Self {
        DenseMat { rows, cols, data: rng.gaussian_vec(rows * cols) }
    }

    /// Entries uniform in [0, scale).
    pub fn uniform(rows: usize, cols: usize, scale: f64, rng: &mut Pcg64) -> Self {
        let data = (0..rows * cols).map(|_| rng.uniform() * scale).collect();
        DenseMat { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Column j as a fresh Vec. Setup/test convenience only — hot paths
    /// must use the allocation-free [`DenseMat::col_iter`] /
    /// [`DenseMat::col_into`] instead.
    pub fn col(&self, j: usize) -> Vec<f64> {
        self.col_iter(j).collect()
    }

    /// Allocation-free strided walk down column j (row-major storage, so
    /// the stride is `cols`). Hard bounds check: a strided walk from an
    /// out-of-range start would yield a plausible-looking wrong column
    /// rather than a panic, so this must not be a debug-only assert.
    #[inline]
    pub fn col_iter(&self, j: usize) -> impl Iterator<Item = f64> + '_ {
        assert!(j < self.cols, "col_iter: column {j} out of {} columns", self.cols);
        self.data
            .get(j..)
            .unwrap_or(&[])
            .iter()
            .step_by(self.cols)
            .copied()
    }

    /// Copy column j into a pre-allocated buffer (hot-path form).
    pub fn col_into(&self, j: usize, out: &mut [f64]) {
        assert_eq!(out.len(), self.rows, "col_into: buffer must hold {} rows", self.rows);
        for (o, v) in out.iter_mut().zip(self.col_iter(j)) {
            *o = v;
        }
    }

    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self.set(i, j, v[i]);
        }
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn transpose(&self) -> DenseMat {
        let mut out = DenseMat::zeros(self.cols, self.rows);
        self.transpose_into(&mut out);
        out
    }

    /// Transpose into a pre-allocated (cols×rows) output — the hot-path
    /// form used by the HALS workspace sweep.
    pub fn transpose_into(&self, out: &mut DenseMat) {
        assert_eq!(out.shape(), (self.cols, self.rows), "transpose_into shape");
        // blocked transpose for cache friendliness on big matrices
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
    }

    /// Gather rows by index into a new matrix (the row-sampling S·A).
    pub fn gather_rows(&self, idx: &[usize]) -> DenseMat {
        let mut out = DenseMat::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.row(i));
        }
        out
    }

    /// Gather rows and scale row r by `scale[r]` (leverage-score rescaling
    /// 1/√(s·p_i) of Eq. 2.11 applied during the gather).
    pub fn gather_rows_scaled(&self, idx: &[usize], scale: &[f64]) -> DenseMat {
        let mut out = DenseMat::zeros(idx.len(), self.cols);
        self.gather_rows_scaled_into(idx, scale, &mut out);
        out
    }

    /// Scaled row gather into a pre-allocated output (hot-path form for
    /// the LvS workspace). `out` is resized to `idx.len()` rows; as long
    /// as its initial capacity covers the largest sample count (the
    /// workspace pre-sizes it to s×k), no reallocation happens. The
    /// per-row scale-copy runs on the fused bitwise-tier
    /// [`simd::scale_into`] kernel (IEEE multiplication commutes, so the
    /// vectorized `s·v` is bit-identical to the scalar `v·s`).
    pub fn gather_rows_scaled_into(&self, idx: &[usize], scale: &[f64], out: &mut DenseMat) {
        assert_eq!(idx.len(), scale.len());
        assert_eq!(out.cols, self.cols, "gather_rows_scaled_into column mismatch");
        out.rows = idx.len();
        out.data.resize(idx.len() * self.cols, 0.0);
        let isa = simd::active();
        for (r, (&i, &s)) in idx.iter().zip(scale.iter()).enumerate() {
            simd::scale_into(isa, s, self.row(i), out.row_mut(r));
        }
    }

    pub fn fro_norm_sq(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum()
    }

    pub fn fro_norm(&self) -> f64 {
        self.fro_norm_sq().sqrt()
    }

    /// max entry
    pub fn max_value(&self) -> f64 {
        self.data.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// mean of all entries (the ζ of the §5 init strategy)
    pub fn mean(&self) -> f64 {
        self.data.iter().sum::<f64>() / (self.data.len() as f64)
    }

    /// self += alpha * other
    pub fn axpy(&mut self, alpha: f64, other: &DenseMat) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += alpha * b;
        }
    }

    /// Overwrite all entries of self with `other` (same shape, no
    /// reallocation — the workspace-preserving assignment).
    pub fn copy_from(&mut self, other: &DenseMat) {
        assert_eq!(self.shape(), other.shape(), "copy_from shape mismatch");
        self.data.copy_from_slice(&other.data);
    }

    /// Set every entry to `v`.
    pub fn fill(&mut self, v: f64) {
        self.data.fill(v);
    }

    /// Add `alpha` to the diagonal (the +αI regularization of Eq. 2.4),
    /// in place.
    pub fn add_diag(&mut self, alpha: f64) {
        assert_eq!(self.rows, self.cols, "add_diag needs a square matrix");
        for i in 0..self.rows {
            self.data[i * self.cols + i] += alpha;
        }
    }

    pub fn scale(&mut self, alpha: f64) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    /// Projection onto the nonnegative orthant, [·]_+ in the paper.
    pub fn project_nonneg(&mut self) {
        for a in self.data.iter_mut() {
            if *a < 0.0 {
                *a = 0.0;
            }
        }
    }

    pub fn is_nonneg(&self) -> bool {
        self.data.iter().all(|&x| x >= 0.0)
    }

    /// ‖self − other‖_F
    pub fn diff_fro(&self, other: &DenseMat) -> f64 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }

    /// Symmetrize in place: A ← (A + Aᵀ)/2.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self.at(i, j) + self.at(j, i));
                self.set(i, j, v);
                self.set(j, i, v);
            }
        }
    }

    /// f32 copy (PJRT boundary; also the staging downcast of the
    /// reduced-precision compute path of the sketched pipelines — see
    /// [`crate::linalg::simd`]'s f32 tier, whose GEMMs consume these
    /// buffers with f64 accumulation).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    /// f32 conversion into a reusable buffer (PJRT boundary and the
    /// `SYMNMF_PRECISION=f32` staging path, hot-path form: the staging
    /// allocation happens once per solve, not per call).
    pub fn write_f32_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(self.data.len());
        out.extend(self.data.iter().map(|&x| x as f32));
    }

    /// From an f32 buffer (PJRT boundary).
    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> DenseMat {
        assert_eq!(data.len(), rows * cols);
        DenseMat { rows, cols, data: data.iter().map(|&x| x as f64).collect() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_ops() {
        let a = DenseMat::from_fn(3, 2, |i, j| (i * 2 + j) as f64);
        assert_eq!(a.at(2, 1), 5.0);
        assert_eq!(a.row(1), &[2.0, 3.0]);
        assert_eq!(a.col(0), vec![0.0, 2.0, 4.0]);
        let t = a.transpose();
        assert_eq!(t.shape(), (2, 3));
        assert_eq!(t.at(1, 2), 5.0);
    }

    #[test]
    fn col_iter_and_col_into_match_col() {
        let a = DenseMat::from_fn(4, 3, |i, j| (i * 3 + j) as f64);
        for j in 0..3 {
            let want = a.col(j);
            let got: Vec<f64> = a.col_iter(j).collect();
            assert_eq!(got, want, "col {j}");
            let mut buf = vec![0.0; 4];
            a.col_into(j, &mut buf);
            assert_eq!(buf, want, "col_into {j}");
        }
        // degenerate: zero-row matrix yields an empty walk
        let e = DenseMat::zeros(0, 2);
        assert_eq!(e.col_iter(1).count(), 0);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::seed_from_u64(1);
        let a = DenseMat::gaussian(37, 53, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn gather_rows_scaled_matches_manual() {
        let a = DenseMat::from_fn(4, 3, |i, j| (i * 3 + j) as f64);
        let g = a.gather_rows_scaled(&[2, 0, 2], &[2.0, 1.0, 0.5]);
        assert_eq!(g.row(0), &[12.0, 14.0, 16.0]);
        assert_eq!(g.row(1), &[0.0, 1.0, 2.0]);
        assert_eq!(g.row(2), &[3.0, 3.5, 4.0]);
    }

    #[test]
    fn project_nonneg_and_norms() {
        let mut a = DenseMat::from_vec(2, 2, vec![1.0, -2.0, 3.0, -4.0]);
        assert!(!a.is_nonneg());
        a.project_nonneg();
        assert!(a.is_nonneg());
        assert_eq!(a.fro_norm_sq(), 10.0);
    }

    #[test]
    fn symmetrize_works() {
        let mut a = DenseMat::from_vec(2, 2, vec![1.0, 2.0, 4.0, 5.0]);
        a.symmetrize();
        assert_eq!(a.at(0, 1), 3.0);
        assert_eq!(a.at(1, 0), 3.0);
    }

    #[test]
    fn transpose_into_matches_transpose() {
        let mut rng = Pcg64::seed_from_u64(3);
        let a = DenseMat::gaussian(41, 19, &mut rng);
        let mut out = DenseMat::zeros(19, 41);
        a.transpose_into(&mut out);
        assert_eq!(out, a.transpose());
    }

    #[test]
    fn gather_into_resizes_without_realloc() {
        let a = DenseMat::from_fn(6, 3, |i, j| (i * 3 + j) as f64);
        let mut out = DenseMat::zeros(4, 3); // capacity for 4 rows
        let ptr = out.data().as_ptr();
        a.gather_rows_scaled_into(&[1, 5], &[1.0, 2.0], &mut out);
        assert_eq!(out.shape(), (2, 3));
        assert_eq!(out.row(1), &[30.0, 32.0, 34.0]);
        a.gather_rows_scaled_into(&[0, 1, 2, 3], &[1.0; 4], &mut out);
        assert_eq!(out.shape(), (4, 3));
        assert_eq!(out.data().as_ptr(), ptr, "buffer must not reallocate");
        assert_eq!(out, a.gather_rows_scaled(&[0, 1, 2, 3], &[1.0; 4]));
    }

    #[test]
    fn copy_from_fill_add_diag() {
        let a = DenseMat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let mut b = DenseMat::zeros(2, 2);
        b.copy_from(&a);
        assert_eq!(a, b);
        b.add_diag(0.5);
        assert_eq!(b.at(0, 0), 1.5);
        assert_eq!(b.at(1, 1), 4.5);
        assert_eq!(b.at(0, 1), 2.0);
        b.fill(7.0);
        assert!(b.data().iter().all(|&x| x == 7.0));
    }

    #[test]
    fn f32_roundtrip() {
        let mut rng = Pcg64::seed_from_u64(2);
        let a = DenseMat::gaussian(5, 7, &mut rng);
        let b = DenseMat::from_f32(5, 7, &a.to_f32());
        assert!(a.diff_fro(&b) < 1e-5);
    }
}
