#!/usr/bin/env python3
"""Kernel bench regression gate.

Compares the freshly generated BENCH_kernels.json against the committed
baseline, prints the per-kernel GFLOP/s delta table, and fails (exit 1)
when the gated kernel row regresses by more than the allowed fraction.

Only the gate row is enforced: micro-benchmark noise on shared CI runners
makes a hard gate on every row too flaky, but the m=2048/k=32 symmetric
dense X*F product runs long enough to be stable (ROADMAP "Perf trajectory
tracking").

Bootstrap behaviour: if the baseline has no measurement for the gate row
(e.g. the committed file is the empty bootstrap placeholder produced
before any machine ran the bench), the check passes with a notice so the
first CI run can publish real numbers to commit as the next baseline.
"""

import argparse
import json
import sys


def load_rows(path):
    with open(path) as fh:
        doc = json.load(fh)
    rows = {}
    for rec in doc.get("kernels", []):
        rows[(rec["op"], rec.get("shape", ""))] = rec
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True, help="committed BENCH_kernels.json")
    ap.add_argument("--current", required=True, help="freshly generated BENCH_kernels.json")
    ap.add_argument(
        "--gate-op",
        default="dense_xf_apply_into",
        help="kernel op whose GFLOP/s regression fails the job",
    )
    ap.add_argument(
        "--max-regression",
        type=float,
        default=0.05,
        help="allowed fractional GFLOP/s drop on the gate row (default 5%%)",
    )
    args = ap.parse_args()

    base = load_rows(args.baseline)
    cur = load_rows(args.current)

    print(f"{'op':<24} {'shape':<24} {'base GF/s':>10} {'cur GF/s':>10} {'delta':>8}")
    for key in sorted(cur):
        op, shape = key
        c = cur[key]
        b = base.get(key)
        if b is None or b.get("gflops", 0.0) <= 0.0:
            delta = "  (new)"
            bg = "-"
        else:
            bgf = b["gflops"]
            delta = f"{100.0 * (c.get('gflops', 0.0) - bgf) / bgf:+7.1f}%"
            bg = f"{bgf:10.2f}"
        cg = c.get("gflops", 0.0)
        print(f"{op:<24} {shape:<24} {bg:>10} {cg:>10.2f} {delta:>8}")

    gate_base = [r for (op, _), r in base.items() if op == args.gate_op]
    gate_cur = [r for (op, _), r in cur.items() if op == args.gate_op]
    if not gate_cur:
        print(f"ERROR: current run has no '{args.gate_op}' row", file=sys.stderr)
        return 1
    if not gate_base or gate_base[0].get("gflops", 0.0) <= 0.0:
        print(
            f"NOTICE: baseline has no measured '{args.gate_op}' row "
            "(bootstrap) — passing; commit the generated BENCH_kernels.json "
            "as the new baseline."
        )
        return 0
    bgf = gate_base[0]["gflops"]
    cgf = gate_cur[0].get("gflops", 0.0)
    floor = bgf * (1.0 - args.max_regression)
    if cgf < floor:
        print(
            f"FAIL: {args.gate_op} regressed: {cgf:.2f} GF/s < "
            f"{floor:.2f} GF/s ({bgf:.2f} baseline, "
            f"-{args.max_regression:.0%} allowed)",
            file=sys.stderr,
        )
        return 1
    print(
        f"OK: {args.gate_op} at {cgf:.2f} GF/s vs baseline {bgf:.2f} GF/s "
        f"(floor {floor:.2f})"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
