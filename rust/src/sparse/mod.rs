//! Sparse matrix substrate (CSR) for the paper's large sparse experiments
//! (§5.2, the OAG citation graph): SpMM against dense skinny factors,
//! sampled products for LvS-SymNMF, symmetric normalization, and
//! MatrixMarket IO.

pub mod csr;
pub mod io;
pub mod sym;

pub use csr::CsrMat;
