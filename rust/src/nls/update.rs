//! The paper's Update(G, Y) abstraction (App. E): a single entry point
//! dispatching to BPP / HALS / MU, so every SymNMF driver (exact, LAI,
//! LvS, compressed) shares one code path for the solve phase.

use crate::linalg::DenseMat;
use crate::nls::{bpp, hals, mu};

/// Which NLS update rule to run inside an alternating iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateRule {
    /// Block Principal Pivoting — exact NLS solve per row (Kim & Park).
    Bpp,
    /// Hierarchical ALS — one exact coordinate sweep over columns.
    Hals,
    /// Multiplicative updates (Lee & Seung).
    Mu,
}

impl UpdateRule {
    pub fn label(&self) -> &'static str {
        match self {
            UpdateRule::Bpp => "BPP",
            UpdateRule::Hals => "HALS",
            UpdateRule::Mu => "MU",
        }
    }

    pub fn parse(s: &str) -> Option<UpdateRule> {
        match s.to_ascii_lowercase().as_str() {
            "bpp" => Some(UpdateRule::Bpp),
            "hals" => Some(UpdateRule::Hals),
            "mu" => Some(UpdateRule::Mu),
            _ => None,
        }
    }
}

/// Update the factor given the normal-equations pair:
/// G = FᵀF (+αI), Y = X·F (+αF), warm start `w`. Returns the new factor
/// (m×k, nonnegative).
pub fn update(rule: UpdateRule, g: &DenseMat, y: &DenseMat, w: &DenseMat) -> DenseMat {
    match rule {
        UpdateRule::Bpp => bpp::solve_multi(g, y, Some(w)),
        UpdateRule::Hals => {
            let mut out = w.clone();
            hals::hals_sweep(g, y, &mut out);
            hals::fix_zero_columns(&mut out, 1e-14);
            out
        }
        UpdateRule::Mu => {
            let mut out = w.clone();
            mu::mu_update(g, y, &mut out);
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;
    use crate::util::rng::Pcg64;

    /// All rules decrease the quadratic surrogate from the same start.
    #[test]
    fn all_rules_decrease_objective() {
        let mut rng = Pcg64::seed_from_u64(21);
        let (m, k) = (30, 4);
        let u = DenseMat::uniform(m, k, 1.0, &mut rng);
        let x = blas::matmul_nt(&u, &u);
        let h = DenseMat::uniform(m, k, 1.0, &mut rng);
        let w0 = DenseMat::uniform(m, k, 1.0, &mut rng);
        let g = blas::gram(&h);
        let y = blas::matmul(&x, &h);
        let obj = |wm: &DenseMat| {
            let rec = blas::matmul_nt(wm, &h);
            let mut d = x.clone();
            d.axpy(-1.0, &rec);
            d.fro_norm_sq()
        };
        let before = obj(&w0);
        for rule in [UpdateRule::Bpp, UpdateRule::Hals, UpdateRule::Mu] {
            let w = update(rule, &g, &y, &w0);
            assert!(w.is_nonneg(), "{rule:?}");
            let after = obj(&w);
            assert!(after <= before + 1e-9, "{rule:?}: {before} → {after}");
        }
    }

    /// BPP gives the global row-wise optimum → its objective is ≤ HALS/MU
    /// after a single update from the same state.
    #[test]
    fn bpp_is_at_least_as_good_per_update() {
        let mut rng = Pcg64::seed_from_u64(22);
        let (m, k) = (25, 3);
        let u = DenseMat::uniform(m, k, 1.0, &mut rng);
        let x = blas::matmul_nt(&u, &u);
        let h = DenseMat::uniform(m, k, 1.0, &mut rng);
        let w0 = DenseMat::uniform(m, k, 1.0, &mut rng);
        let g = blas::gram(&h);
        let y = blas::matmul(&x, &h);
        let obj = |wm: &DenseMat| {
            let rec = blas::matmul_nt(wm, &h);
            let mut d = x.clone();
            d.axpy(-1.0, &rec);
            d.fro_norm_sq()
        };
        let o_bpp = obj(&update(UpdateRule::Bpp, &g, &y, &w0));
        let o_hals = obj(&update(UpdateRule::Hals, &g, &y, &w0));
        let o_mu = obj(&update(UpdateRule::Mu, &g, &y, &w0));
        assert!(o_bpp <= o_hals + 1e-8);
        assert!(o_bpp <= o_mu + 1e-8);
    }

    #[test]
    fn parse_labels() {
        assert_eq!(UpdateRule::parse("BPP"), Some(UpdateRule::Bpp));
        assert_eq!(UpdateRule::parse("hals"), Some(UpdateRule::Hals));
        assert_eq!(UpdateRule::parse("nope"), None);
    }
}
