//! Symmetrically regularized alternating updating (paper §2.1.1–2.1.2):
//! iterate the two NLS problems of Eq. 2.4,
//!
//! ```text
//!     min_{W≥0} ‖[H; √αI]·Wᵀ − [X; √αHᵀ]‖   and symmetrically for H,
//! ```
//!
//! through their normal-equation pair (G = FᵀF + αI, Y = X·F + αF) and
//! the Update(G, Y) rule (BPP / HALS / MU). This single loop, generic
//! over [`SymOp`], is also the engine of LAI-SymNMF (X replaced by the
//! factored approximation) and Compressed-NMF (projected products).

use crate::linalg::{blas, DenseMat, IterWorkspace};
use crate::nls::update_into;
use crate::randnla::SymOp;
use crate::symnmf::convergence::{normalized_residual, projected_gradient_norm_sym};
use crate::symnmf::init::initial_factor;
use crate::symnmf::metrics::{IterRecord, StopRule, SymNmfResult};
use crate::symnmf::options::SymNmfOptions;
use crate::util::rng::Pcg64;
use crate::util::timer::{PhaseTimer, Stopwatch, PHASE_MM, PHASE_SOLVE};

/// Exact-metric evaluator: residual (and optional projected gradient)
/// against the TRUE data matrix, evaluated off the clock so every method
/// is billed only for its own algorithmic work (see `IterRecord`).
pub struct Metrics<'a> {
    pub x: &'a dyn SymOp,
    pub x_norm_sq: f64,
    pub proj_grad: bool,
}

impl<'a> Metrics<'a> {
    pub fn new(x: &'a dyn SymOp, proj_grad: bool) -> Self {
        Metrics { x, x_norm_sq: x.fro_norm_sq(), proj_grad }
    }

    /// (normalized residual of ‖X − WHᵀ‖, optional projected gradient)
    pub fn eval(&self, w: &DenseMat, h: &DenseMat) -> (f64, Option<f64>) {
        let xh = self.x.apply(h);
        let gw = blas::gram(w);
        let gh = blas::gram(h);
        let res = normalized_residual(self.x_norm_sq, &xh, w, &gw, &gh);
        let pg = self
            .proj_grad
            .then(|| projected_gradient_norm_sym(h, &xh, &gh));
        (res, pg)
    }

    /// [`Metrics::eval`] drawing the X·H and Gram buffers from the
    /// iteration workspace (`xh`, `g`, `g2` — all free between
    /// iterations). The residual path allocates nothing; when
    /// `proj_grad` is enabled the projected-gradient evaluation still
    /// builds one m×k H·G product internally (off the clock, see
    /// [`projected_gradient_norm_sym`]).
    pub fn eval_ws(
        &self,
        w: &DenseMat,
        h: &DenseMat,
        ws: &mut IterWorkspace,
    ) -> (f64, Option<f64>) {
        self.x.apply_into(h, &mut ws.xh);
        blas::gram_into(w, &mut ws.g2);
        blas::gram_into(h, &mut ws.g);
        let res = normalized_residual(self.x_norm_sq, &ws.xh, w, &ws.g2, &ws.g);
        let pg = self
            .proj_grad
            .then(|| projected_gradient_norm_sym(h, &ws.xh, &ws.g));
        (res, pg)
    }
}

/// Resolve α: the paper's recommendation α = max(X) (§5.1, from [35]).
pub fn resolve_alpha<X: SymOp + ?Sized>(x: &X, opts: &SymNmfOptions) -> f64 {
    opts.alpha.unwrap_or_else(|| x.max_value())
}

/// The shared alternating loop. `x` is whatever operator the caller wants
/// the iteration to see (true X, LAI, …); `metrics` always measures
/// against the true X. `setup_secs` pre-loads the clock (LAI build time).
/// Sizes a fresh [`IterWorkspace`] from (m, k) and delegates to
/// [`run_alternating_loop_ws`].
#[allow(clippy::too_many_arguments)]
pub fn run_alternating_loop(
    x: &dyn SymOp,
    alpha: f64,
    opts: &SymNmfOptions,
    h: DenseMat,
    metrics: &Metrics,
    label: String,
    setup_secs: f64,
    phases: PhaseTimer,
) -> SymNmfResult {
    let mut ws = IterWorkspace::new(x.dim(), opts.k);
    run_alternating_loop_ws(x, alpha, opts, h, metrics, label, setup_secs, phases, &mut ws)
}

/// The alternating loop against a caller-provided workspace. The
/// steady-state iteration performs no heap allocation: X·F products land
/// in `ws.y` via [`SymOp::apply_into`], Gram matrices in `ws.g` via
/// [`blas::gram_into`], and the Update(G, Y) rules draw their scratch
/// from `ws.update` (see [`crate::linalg::workspace`]).
#[allow(clippy::too_many_arguments)]
pub fn run_alternating_loop_ws(
    x: &dyn SymOp,
    alpha: f64,
    opts: &SymNmfOptions,
    mut h: DenseMat,
    metrics: &Metrics,
    label: String,
    setup_secs: f64,
    phases: PhaseTimer,
    ws: &mut IterWorkspace,
) -> SymNmfResult {
    let mut w = h.clone();
    let mut records: Vec<IterRecord> = Vec::new();
    let mut stop = StopRule::new(opts.tol, opts.patience);
    let mut phases = phases;
    let mut clock = setup_secs;

    for iter in 0..opts.max_iters {
        let sw = Stopwatch::start();
        let mut mm = 0.0;
        let mut solve = 0.0;

        // --- W update: G = HᵀH + αI, Y = X·H + αH ---
        let t = Stopwatch::start();
        x.apply_into(&h, &mut ws.y);
        blas::gram_into(&h, &mut ws.g);
        mm += t.elapsed_secs();
        ws.g.add_diag(alpha);
        ws.y.axpy(alpha, &h);
        let t = Stopwatch::start();
        update_into(opts.rule, &ws.g, &ws.y, &mut w, &mut ws.update);
        solve += t.elapsed_secs();

        // --- H update: G = WᵀW + αI, Y = X·W + αW ---
        let t = Stopwatch::start();
        x.apply_into(&w, &mut ws.y);
        blas::gram_into(&w, &mut ws.g);
        mm += t.elapsed_secs();
        ws.g.add_diag(alpha);
        ws.y.axpy(alpha, &w);
        let t = Stopwatch::start();
        update_into(opts.rule, &ws.g, &ws.y, &mut h, &mut ws.update);
        solve += t.elapsed_secs();

        clock += sw.elapsed_secs();
        phases.add(PHASE_MM, std::time::Duration::from_secs_f64(mm));
        phases.add(PHASE_SOLVE, std::time::Duration::from_secs_f64(solve));

        // --- metrics, off the clock (workspace buffers are free here) ---
        let (res, pg) = metrics.eval_ws(&w, &h, ws);
        records.push(IterRecord {
            iter,
            time_secs: clock,
            residual: res,
            proj_grad: pg,
            phase_secs: (mm, solve, 0.0),
            hybrid_stats: None,
        });
        if stop.update(res) {
            break;
        }
    }

    SymNmfResult { label, h, w, records, phases, setup_secs }
}

/// Standard SymNMF via regularized ANLS/HALS/MU on the exact X
/// (the paper's deterministic baselines "BPP" and "HALS").
pub fn symnmf_anls<X: SymOp>(x: &X, opts: &SymNmfOptions) -> SymNmfResult {
    let mut rng = Pcg64::seed_from_u64(opts.seed);
    let alpha = resolve_alpha(x, opts);
    let h0 = initial_factor(x, opts, &mut rng);
    let metrics = Metrics::new(x, true);
    run_alternating_loop(
        x,
        alpha,
        opts,
        h0,
        &metrics,
        opts.rule.label().to_string(),
        0.0,
        PhaseTimer::new(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nls::UpdateRule;

    /// A symmetric nonnegative matrix with planted rank-k structure.
    pub fn planted(m: usize, k: usize, noise: f64, seed: u64) -> DenseMat {
        let mut rng = Pcg64::seed_from_u64(seed);
        let h = DenseMat::uniform(m, k, 1.0, &mut rng);
        let mut x = blas::matmul_nt(&h, &h);
        if noise > 0.0 {
            let mut e = DenseMat::uniform(m, m, noise, &mut rng);
            e.symmetrize();
            x.axpy(1.0, &e);
        }
        x.symmetrize();
        x
    }

    #[test]
    fn converges_on_planted_problem_all_rules() {
        let x = planted(60, 4, 0.0, 1);
        for rule in [UpdateRule::Bpp, UpdateRule::Hals, UpdateRule::Mu] {
            let mut opts = SymNmfOptions::new(4).with_rule(rule).with_seed(3);
            opts.max_iters = 150;
            let res = symnmf_anls(&x, &opts);
            assert!(res.h.is_nonneg());
            assert!(res.w.is_nonneg());
            let final_res = res.final_residual();
            assert!(
                final_res < 0.15,
                "{rule:?} residual {final_res} too high"
            );
            // residual roughly decreasing
            let first = res.records.first().unwrap().residual;
            assert!(final_res <= first + 1e-9);
        }
    }

    #[test]
    fn w_and_h_converge_together() {
        // large α forces W ≈ H (the Eq. 2.3 coupling)
        let x = planted(40, 3, 0.0, 2);
        let mut opts = SymNmfOptions::new(3).with_seed(5);
        opts.max_iters = 100;
        let res = symnmf_anls(&x, &opts);
        let rel = res.w.diff_fro(&res.h) / res.h.fro_norm();
        assert!(rel < 0.05, "‖W−H‖/‖H‖ = {rel}");
    }

    /// Acceptance: no heap allocation in the steady-state iteration — all
    /// products, Grams and update scratch come from the pre-sized
    /// workspace, whose buffer pointers must be bit-identical across
    /// iterations (a reallocation or buffer replacement would move them).
    #[test]
    fn workspace_buffers_stable_across_iterations() {
        for rule in [UpdateRule::Bpp, UpdateRule::Hals, UpdateRule::Mu] {
            let x = planted(40, 3, 0.0, 9);
            let mut opts = SymNmfOptions::new(3).with_rule(rule).with_seed(1);
            opts.max_iters = 3;
            let alpha = resolve_alpha(&x, &opts);
            let mut rng = Pcg64::seed_from_u64(2);
            let h0 = initial_factor(&x, &opts, &mut rng);
            let metrics = Metrics::new(&x, true);
            let mut ws = crate::linalg::IterWorkspace::new(40, 3);
            let before = ws.buffer_ptrs();
            let res = run_alternating_loop_ws(
                &x,
                alpha,
                &opts,
                h0,
                &metrics,
                "ws-test".to_string(),
                0.0,
                PhaseTimer::new(),
                &mut ws,
            );
            assert_eq!(res.iters(), 3, "{rule:?}: patience must not fire in 3 iters");
            assert_eq!(
                ws.buffer_ptrs(),
                before,
                "{rule:?}: workspace buffers moved during the hot loop"
            );
            assert!(res.h.is_nonneg());
        }
    }

    #[test]
    fn records_are_monotone_in_time() {
        let x = planted(30, 3, 0.1, 3);
        let mut opts = SymNmfOptions::new(3);
        opts.max_iters = 20;
        let res = symnmf_anls(&x, &opts);
        for w in res.records.windows(2) {
            assert!(w[1].time_secs >= w[0].time_secs);
        }
        assert!(res.iters() <= 20);
    }

    #[test]
    fn stopping_rule_halts_early_on_easy_input() {
        let x = planted(50, 3, 0.0, 4);
        let mut opts = SymNmfOptions::new(3);
        opts.max_iters = 300;
        let res = symnmf_anls(&x, &opts);
        assert!(
            res.iters() < 300,
            "should stop before the cap, took {}",
            res.iters()
        );
    }
}
