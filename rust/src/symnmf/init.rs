//! Factor initialization — the strategy of [35] used throughout §5:
//! entries uniform on [0, 1) scaled by 2·√(ζ/k), ζ = mean(X), so the
//! initial ‖HHᵀ‖ is commensurate with ‖X‖.

use crate::linalg::DenseMat;
use crate::randnla::SymOp;
use crate::util::rng::Pcg64;

/// H₀ ∈ R^{m×k} per the §5 initialization.
pub fn init_factor<X: SymOp>(x: &X, k: usize, rng: &mut Pcg64) -> DenseMat {
    let zeta = x.mean_value().max(0.0);
    let scale = 2.0 * (zeta / k as f64).sqrt();
    DenseMat::uniform(x.dim(), k, scale, rng)
}

/// Resolve the initial factor: the options' warm start if provided (shape
/// checked), else the §5 random initialization.
pub fn initial_factor<X: SymOp>(
    x: &X,
    opts: &crate::symnmf::SymNmfOptions,
    rng: &mut Pcg64,
) -> DenseMat {
    match &opts.warm_start {
        Some(h0) => {
            assert_eq!(
                h0.shape(),
                (x.dim(), opts.k),
                "warm_start shape must be (m, k)"
            );
            h0.clone()
        }
        None => init_factor(x, opts.k, rng),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;

    #[test]
    fn init_norm_is_commensurate() {
        let mut rng = Pcg64::seed_from_u64(1);
        let h_true = DenseMat::uniform(200, 4, 1.0, &mut rng);
        let x = blas::matmul_nt(&h_true, &h_true);
        let h0 = init_factor(&x, 4, &mut rng);
        assert_eq!(h0.shape(), (200, 4));
        assert!(h0.is_nonneg());
        // E[(H₀H₀ᵀ)_ij] = k·(scale²/4)·(uniform moments) ≈ ζ → same order
        let rec = blas::matmul_nt(&h0, &h0);
        let ratio = rec.mean() / x.mean();
        assert!(
            ratio > 0.2 && ratio < 5.0,
            "init scale off by {ratio}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let mut r1 = Pcg64::seed_from_u64(9);
        let mut r2 = Pcg64::seed_from_u64(9);
        let x = DenseMat::eye(10);
        let a = init_factor(&x, 3, &mut r1);
        let b = init_factor(&x, 3, &mut r2);
        assert_eq!(a, b);
    }
}
