//! Pre-sized per-iteration buffer workspace — the zero-allocation
//! substrate of the kernel dispatch layer.
//!
//! The paper's bottleneck analysis (§3, §5.1.1) is about memory traffic
//! as much as flops: every alternating iteration forms the products X·F
//! and FᵀF plus the Update(G, Y) scratch, and the seed implementation
//! allocated each of them afresh — O(m·k) heap churn, thousands of times
//! per solve. [`IterWorkspace`] holds all of those buffers, sized once
//! from (m, k) (plus the LvS sample budget s), so the steady-state
//! iteration of every solver engine driven by the shared outer loop
//! ([`run_solver`]) — ANLS/HALS/MU, LvS, PGNCG, Compressed — and of the
//! frozen reference loops ([`run_alternating_loop`]) performs **no heap
//! allocation**: X·F products land in [`IterWorkspace::y`] via
//! [`SymOp::apply_into`], Gram matrices in [`IterWorkspace::g`] via
//! [`gram_into`], and the update rules draw their scratch from
//! [`UpdateScratch`].
//!
//! The protocol is enforced by tests that run several iterations and
//! assert the buffer data pointers ([`IterWorkspace::buffer_ptrs`]) are
//! bit-identical before and after — a reallocation (or a buffer replaced
//! by assignment) would move them.
//!
//! [`run_solver`]: crate::symnmf::engine::run_solver
//! [`run_alternating_loop`]: crate::symnmf::anls::run_alternating_loop
//! [`SymOp::apply_into`]: crate::randnla::SymOp::apply_into
//! [`gram_into`]: crate::linalg::blas::gram_into

use crate::linalg::DenseMat;
use crate::util::rng::AliasTable;

/// Reusable packing target for the tile-major B panels of the packed NT
/// microkernel (see the `linalg::blas` header): capacity grows to the
/// largest packed operand requested and is then reused, so steady-state
/// panel packing performs no heap allocation. `blas` holds one per
/// thread (thread-local), mirroring the accumulator-pool pattern, so
/// batched trial workers never contend on a shared buffer.
#[derive(Debug, Default)]
pub struct PanelBuf {
    data: Vec<f64>,
}

impl PanelBuf {
    pub fn new() -> PanelBuf {
        PanelBuf { data: Vec::new() }
    }

    /// A zeroed-capacity packing target of exactly `len` elements. Grows
    /// (amortized, geometric) only when `len` exceeds every previous
    /// request on this buffer; the packing routines overwrite the full
    /// slice, so stale contents never leak into a product.
    pub fn packed(&mut self, len: usize) -> &mut [f64] {
        if self.data.len() < len {
            self.data.resize(len, 0.0);
        }
        &mut self.data[..len]
    }

    /// Data pointer, for allocation-stability assertions in tests.
    pub fn as_ptr(&self) -> *const f64 {
        self.data.as_ptr()
    }
}

/// Grow-only f32 staging buffer for the reduced-precision compute path
/// of the sketched pipelines (`SYMNMF_PRECISION=f32`): f64 factors are
/// downcast into it before the f32 inner GEMMs, so the steady-state f32
/// iteration allocates nothing — the f32 twin of [`PanelBuf`].
#[derive(Debug, Default)]
pub struct F32Buf {
    data: Vec<f32>,
}

impl F32Buf {
    pub fn new() -> F32Buf {
        F32Buf { data: Vec::new() }
    }

    /// Overwrite the buffer with the f32 downcast of `src` and return
    /// the staged slice. Capacity grows to the largest request and is
    /// then reused (amortized, geometric — `Vec::resize` never shrinks
    /// the allocation).
    pub fn stage(&mut self, src: &[f64]) -> &[f32] {
        if self.data.len() < src.len() {
            self.data.resize(src.len(), 0.0);
        }
        for (d, &s) in self.data.iter_mut().zip(src) {
            *d = s as f32;
        }
        &self.data[..src.len()]
    }

    /// Data pointer, for allocation-stability assertions in tests.
    pub fn as_ptr(&self) -> *const f32 {
        self.data.as_ptr()
    }
}

/// Scratch buffers for the Update(G, Y) rules (BPP / HALS / MU), shared
/// across rules so one workspace serves whatever `opts.rule` selects:
///
/// * BPP writes its fresh solve into `out`, then copies back into the
///   factor (BPP is warm-start-free by construction, matching [33]);
/// * HALS runs the transpose-free row-major sweep fully in place and
///   needs no scratch at all (the k×m `ft`/`yt` staging transposes and
///   the per-column delta buffer of the previous implementation are
///   gone — 2·m·k·8 bytes less traffic per sweep);
/// * MU uses `out` for the W·G denominator product.
#[derive(Debug)]
pub struct UpdateScratch {
    /// m×k: BPP output / MU's W·G product
    pub out: DenseMat,
}

impl UpdateScratch {
    pub fn new(m: usize, k: usize) -> UpdateScratch {
        UpdateScratch { out: DenseMat::zeros(m, k) }
    }
}

/// Persistent buffers of the LvS sampling pipeline (leverage scores →
/// hybrid draw → rescale weights), so `LvsEngine::step` allocates
/// nothing once warm: the leverage/residual vectors and the alias table
/// are grow-only, the CholeskyQR scratch is k×k-fixed, and the sample
/// index/scale/weight outputs are capacity-pinned at the budget s. The
/// only warmup allocation is the first alias-table rebuild (its size is
/// data-dependent); everything after iteration one is reuse.
#[derive(Debug)]
pub struct SampleWorkspace {
    /// m leverage scores l_i = ‖R⁻ᵀ f_i‖² (grow-only)
    pub leverage: Vec<f64>,
    /// k×k Gram FᵀF of the CholeskyQR leverage pass
    pub chol_g: DenseMat,
    /// k×k jitter scratch (holds A + εI on Cholesky retries)
    pub chol_scratch: DenseMat,
    /// k×k upper Cholesky factor R
    pub chol_r: DenseMat,
    /// k-sized forward-substitution buffer
    pub z: Vec<f64>,
    /// rebuildable alias table for the random draw
    pub table: AliasTable,
    /// m residual weights (leverage with deterministic rows zeroed)
    pub resid: Vec<f64>,
    /// deterministically included row indices (θ-mass rows of §4.2)
    pub det: Vec<usize>,
    /// sampled row indices i_r (deterministic rows first)
    pub indices: Vec<usize>,
    /// rescale factors c_r
    pub scales: Vec<f64>,
    /// squared rescale factors c_r² — the `sampled_apply_into` weights
    pub weights_sq: Vec<f64>,
}

impl SampleWorkspace {
    /// Buffers for an m×k factor under sample budget `s`; `s == 0`
    /// (non-sampling drivers) holds no allocation at all — every buffer
    /// is grow-only, so a zero-sized workspace still works, it just
    /// warms up lazily.
    pub fn new(m: usize, k: usize, s: usize) -> SampleWorkspace {
        let (m, k, s) = if s == 0 { (0, 0, 0) } else { (m, k, s) };
        SampleWorkspace {
            leverage: Vec::with_capacity(m),
            chol_g: DenseMat::zeros(k, k),
            chol_scratch: DenseMat::zeros(k, k),
            chol_r: DenseMat::zeros(k, k),
            z: vec![0.0; k],
            table: AliasTable::empty(),
            resid: Vec::with_capacity(m),
            det: Vec::with_capacity(m),
            indices: Vec::with_capacity(s),
            scales: Vec::with_capacity(s),
            weights_sq: Vec::with_capacity(s),
        }
    }

    /// Data pointers of every buffer (see [`IterWorkspace::buffer_ptrs`]).
    pub fn buffer_ptrs(&self) -> Vec<*const f64> {
        let [tp, ta] = self.table.buffer_ptrs();
        vec![
            self.leverage.as_ptr(),
            self.chol_g.data().as_ptr(),
            self.chol_scratch.data().as_ptr(),
            self.chol_r.data().as_ptr(),
            self.z.as_ptr(),
            tp,
            ta,
            self.resid.as_ptr(),
            self.det.as_ptr() as *const f64,
            self.indices.as_ptr() as *const f64,
            self.scales.as_ptr(),
            self.weights_sq.as_ptr(),
        ]
    }
}

/// All per-iteration buffers of one SymNMF solve, sized once up front.
#[derive(Debug)]
pub struct IterWorkspace {
    /// m×k RHS buffer: X·F (+ αF) — the target of `apply_into` /
    /// `sampled_apply_into`
    pub y: DenseMat,
    /// k×k Gram buffer: FᵀF (+ αI)
    pub g: DenseMat,
    /// second k×k Gram buffer (metrics need WᵀW and HᵀH simultaneously)
    pub g2: DenseMat,
    /// m×k product buffer for off-the-clock metric evaluation (X·H)
    pub xh: DenseMat,
    /// s×k gathered sampled-factor rows (LvS only; 0×k otherwise). Its
    /// row count tracks the actual sample draw but its capacity is fixed
    /// at s·k, so regrowth never reallocates.
    pub sf: DenseMat,
    /// Update(G, Y) rule scratch
    pub update: UpdateScratch,
    /// LvS sampling pipeline buffers (empty for non-sampling drivers)
    pub sample: SampleWorkspace,
}

impl IterWorkspace {
    /// Workspace for the dense/LAI/compressed drivers (no sampling).
    pub fn new(m: usize, k: usize) -> IterWorkspace {
        IterWorkspace::with_samples(m, k, 0)
    }

    /// Workspace including the LvS gather buffer for `s` row samples.
    pub fn with_samples(m: usize, k: usize, s: usize) -> IterWorkspace {
        IterWorkspace {
            y: DenseMat::zeros(m, k),
            g: DenseMat::zeros(k, k),
            g2: DenseMat::zeros(k, k),
            xh: DenseMat::zeros(m, k),
            sf: DenseMat::zeros(s, k),
            update: UpdateScratch::new(m, k),
            sample: SampleWorkspace::new(m, k, s),
        }
    }

    /// Data pointers of every buffer. The zero-allocation tests capture
    /// these before a run and assert equality after: any per-iteration
    /// reallocation or buffer replacement moves at least one of them.
    pub fn buffer_ptrs(&self) -> Vec<*const f64> {
        let mut ptrs = vec![
            self.y.data().as_ptr(),
            self.g.data().as_ptr(),
            self.g2.data().as_ptr(),
            self.xh.data().as_ptr(),
            self.sf.data().as_ptr(),
            self.update.out.data().as_ptr(),
        ];
        ptrs.extend(self.sample.buffer_ptrs());
        ptrs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// PanelBuf grows once and then serves smaller requests from the
    /// same allocation (the steady-state zero-allocation property the
    /// packed matmul paths rely on).
    #[test]
    fn panel_buf_reuses_allocation() {
        let mut buf = PanelBuf::new();
        let big = buf.packed(1024).len();
        assert_eq!(big, 1024);
        let ptr = buf.as_ptr();
        assert_eq!(buf.packed(512).len(), 512);
        assert_eq!(buf.as_ptr(), ptr, "shrinking request must not reallocate");
        assert_eq!(buf.packed(1024).len(), 1024);
        assert_eq!(buf.as_ptr(), ptr, "repeat of the high-water mark must not reallocate");
    }

    /// F32Buf stages the downcast without reallocating on repeat or
    /// shrinking requests.
    #[test]
    fn f32_buf_stages_and_reuses_allocation() {
        let mut buf = F32Buf::new();
        let src: Vec<f64> = (0..64).map(|i| i as f64 * 0.5).collect();
        let staged = buf.stage(&src);
        assert_eq!(staged.len(), 64);
        for (s, d) in src.iter().zip(staged) {
            assert_eq!(*d, *s as f32);
        }
        let ptr = buf.as_ptr();
        assert_eq!(buf.stage(&src[..16]).len(), 16);
        assert_eq!(buf.as_ptr(), ptr, "shrinking request must not reallocate");
        assert_eq!(buf.stage(&src).len(), 64);
        assert_eq!(buf.as_ptr(), ptr, "repeat of the high-water mark must not reallocate");
    }

    #[test]
    fn shapes_are_consistent() {
        let ws = IterWorkspace::with_samples(20, 4, 7);
        assert_eq!(ws.y.shape(), (20, 4));
        assert_eq!(ws.g.shape(), (4, 4));
        assert_eq!(ws.g2.shape(), (4, 4));
        assert_eq!(ws.xh.shape(), (20, 4));
        assert_eq!(ws.sf.shape(), (7, 4));
        assert_eq!(ws.update.out.shape(), (20, 4));
        assert_eq!(ws.sample.chol_g.shape(), (4, 4));
        assert_eq!(ws.sample.chol_r.shape(), (4, 4));
        assert_eq!(ws.sample.z.len(), 4);
        assert_eq!(ws.buffer_ptrs().len(), 18);
    }

    /// Without a sample budget the sampling pipeline holds no buffers
    /// (the non-LvS drivers must not pay for it).
    #[test]
    fn zero_budget_sample_workspace_is_empty() {
        let ws = IterWorkspace::new(20, 4);
        assert_eq!(ws.sample.chol_g.shape(), (0, 0));
        assert_eq!(ws.sample.leverage.capacity(), 0);
        assert_eq!(ws.sample.z.len(), 0);
    }
}
