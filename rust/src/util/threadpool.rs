//! Scoped data-parallel helpers (rayon is unavailable offline).
//!
//! `parallel_for_chunks` splits an index range into contiguous chunks and
//! runs them on `std::thread::scope` workers. On this image (1 core) it
//! degrades gracefully to a sequential loop with no thread spawns; on
//! multicore machines the dense kernels in `linalg::blas` pick it up.

/// Number of worker threads to use: `SYMNMF_THREADS` env or available
/// parallelism.
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("SYMNMF_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Run `body(lo, hi)` over disjoint subranges covering `0..n` in parallel.
/// `body` must be safe to run concurrently on disjoint ranges.
pub fn parallel_for_chunks<F>(n: usize, min_chunk: usize, body: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let nt = num_threads();
    if nt <= 1 || n <= min_chunk {
        body(0, n);
        return;
    }
    let chunks = nt.min(n.div_ceil(min_chunk)).max(1);
    let per = n.div_ceil(chunks);
    std::thread::scope(|s| {
        for c in 0..chunks {
            let lo = c * per;
            let hi = ((c + 1) * per).min(n);
            if lo >= hi {
                break;
            }
            let body = &body;
            s.spawn(move || body(lo, hi));
        }
    });
}

/// Map over `0..n`, writing results into a pre-allocated vec (each index
/// written exactly once by one worker).
pub fn parallel_map_into<T: Send + Sync, F>(out: &mut [T], min_chunk: usize, f: F)
where
    F: Fn(usize, &mut T) + Sync,
{
    let n = out.len();
    let nt = num_threads();
    if nt <= 1 || n <= min_chunk {
        for (i, slot) in out.iter_mut().enumerate() {
            f(i, slot);
        }
        return;
    }
    let chunks = nt.min(n.div_ceil(min_chunk)).max(1);
    let per = n.div_ceil(chunks);
    std::thread::scope(|s| {
        // split_at_mut based partitioning
        let mut rest = out;
        let mut offset = 0usize;
        for _ in 0..chunks {
            let take = per.min(rest.len());
            if take == 0 {
                break;
            }
            let (head, tail) = rest.split_at_mut(take);
            rest = tail;
            let base = offset;
            offset += take;
            let f = &f;
            s.spawn(move || {
                for (i, slot) in head.iter_mut().enumerate() {
                    f(base + i, slot);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn covers_all_indices_once() {
        let n = 1000;
        let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for_chunks(n, 10, |lo, hi| {
            for i in lo..hi {
                counts[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn map_into_writes_each_slot() {
        let mut out = vec![0usize; 257];
        parallel_map_into(&mut out, 8, |i, slot| *slot = i * 2);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * 2);
        }
    }

    #[test]
    fn empty_range_ok() {
        parallel_for_chunks(0, 1, |_, _| panic!("must not be called"));
    }
}
