//! The paper's Update(G, Y) abstraction (App. E): a single entry point
//! dispatching to BPP / HALS / MU, so every SymNMF driver (exact, LAI,
//! LvS, compressed) shares one code path for the solve phase.

use crate::linalg::workspace::UpdateScratch;
use crate::linalg::DenseMat;
use crate::nls::{bpp, hals, mu};

/// Which NLS update rule to run inside an alternating iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateRule {
    /// Block Principal Pivoting — exact NLS solve per row (Kim & Park).
    Bpp,
    /// Hierarchical ALS — one exact coordinate sweep over columns.
    Hals,
    /// Multiplicative updates (Lee & Seung).
    Mu,
}

impl UpdateRule {
    pub fn label(&self) -> &'static str {
        match self {
            UpdateRule::Bpp => "BPP",
            UpdateRule::Hals => "HALS",
            UpdateRule::Mu => "MU",
        }
    }

    pub fn parse(s: &str) -> Option<UpdateRule> {
        match s.to_ascii_lowercase().as_str() {
            "bpp" => Some(UpdateRule::Bpp),
            "hals" => Some(UpdateRule::Hals),
            "mu" => Some(UpdateRule::Mu),
            _ => None,
        }
    }
}

/// Update the factor given the normal-equations pair:
/// G = FᵀF (+αI), Y = X·F (+αF), warm start `w`. Returns the new factor
/// (m×k, nonnegative). Allocating wrapper over [`update_into`].
pub fn update(rule: UpdateRule, g: &DenseMat, y: &DenseMat, w: &DenseMat) -> DenseMat {
    let mut out = w.clone();
    let mut ws = UpdateScratch::new(y.rows(), y.cols());
    update_into(rule, g, y, &mut out, &mut ws);
    out
}

/// In-place Update(G, Y): the factor `f` is overwritten with the updated
/// iterate, all scratch drawn from the pre-sized [`UpdateScratch`] — the
/// hot-path form every driver loop calls. Semantics per rule:
///
/// * **BPP** solves each row QP exactly from the all-active start (the
///   warm start is irrelevant by construction, matching [33]); since the
///   solve never reads its output buffer, it writes straight into `f`.
/// * **HALS** sweeps `f`'s columns fully in place (later columns see
///   earlier updates) via the transpose-free row-major sweep — it needs
///   no scratch at all — then reseeds any dead column.
/// * **MU** rescales `f` entrywise in place.
pub fn update_into(
    rule: UpdateRule,
    g: &DenseMat,
    y: &DenseMat,
    f: &mut DenseMat,
    ws: &mut UpdateScratch,
) {
    match rule {
        UpdateRule::Bpp => {
            bpp::solve_multi_into(g, y, None, f);
        }
        UpdateRule::Hals => {
            hals::hals_sweep(g, y, f);
            hals::fix_zero_columns(f, 1e-14);
        }
        UpdateRule::Mu => {
            mu::mu_update_ws(g, y, f, &mut ws.out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;
    use crate::util::rng::Pcg64;

    /// All rules decrease the quadratic surrogate from the same start.
    #[test]
    fn all_rules_decrease_objective() {
        let mut rng = Pcg64::seed_from_u64(21);
        let (m, k) = (30, 4);
        let u = DenseMat::uniform(m, k, 1.0, &mut rng);
        let x = blas::matmul_nt(&u, &u);
        let h = DenseMat::uniform(m, k, 1.0, &mut rng);
        let w0 = DenseMat::uniform(m, k, 1.0, &mut rng);
        let g = blas::gram(&h);
        let y = blas::matmul(&x, &h);
        let obj = |wm: &DenseMat| {
            let rec = blas::matmul_nt(wm, &h);
            let mut d = x.clone();
            d.axpy(-1.0, &rec);
            d.fro_norm_sq()
        };
        let before = obj(&w0);
        for rule in [UpdateRule::Bpp, UpdateRule::Hals, UpdateRule::Mu] {
            let w = update(rule, &g, &y, &w0);
            assert!(w.is_nonneg(), "{rule:?}");
            let after = obj(&w);
            assert!(after <= before + 1e-9, "{rule:?}: {before} → {after}");
        }
    }

    /// BPP gives the global row-wise optimum → its objective is ≤ HALS/MU
    /// after a single update from the same state.
    #[test]
    fn bpp_is_at_least_as_good_per_update() {
        let mut rng = Pcg64::seed_from_u64(22);
        let (m, k) = (25, 3);
        let u = DenseMat::uniform(m, k, 1.0, &mut rng);
        let x = blas::matmul_nt(&u, &u);
        let h = DenseMat::uniform(m, k, 1.0, &mut rng);
        let w0 = DenseMat::uniform(m, k, 1.0, &mut rng);
        let g = blas::gram(&h);
        let y = blas::matmul(&x, &h);
        let obj = |wm: &DenseMat| {
            let rec = blas::matmul_nt(wm, &h);
            let mut d = x.clone();
            d.axpy(-1.0, &rec);
            d.fro_norm_sq()
        };
        let o_bpp = obj(&update(UpdateRule::Bpp, &g, &y, &w0));
        let o_hals = obj(&update(UpdateRule::Hals, &g, &y, &w0));
        let o_mu = obj(&update(UpdateRule::Mu, &g, &y, &w0));
        assert!(o_bpp <= o_hals + 1e-8);
        assert!(o_bpp <= o_mu + 1e-8);
    }

    /// The in-place form must agree with the allocating form exactly and
    /// must not move the factor's buffer.
    #[test]
    fn update_into_matches_update_and_preserves_buffers() {
        let mut rng = Pcg64::seed_from_u64(23);
        let (m, k) = (20, 4);
        let u = DenseMat::uniform(m, k, 1.0, &mut rng);
        let x = blas::matmul_nt(&u, &u);
        let h = DenseMat::uniform(m, k, 1.0, &mut rng);
        let w0 = DenseMat::uniform(m, k, 1.0, &mut rng);
        let g = blas::gram(&h);
        let y = blas::matmul(&x, &h);
        let mut ws = crate::linalg::workspace::UpdateScratch::new(m, k);
        for rule in [UpdateRule::Bpp, UpdateRule::Hals, UpdateRule::Mu] {
            let want = update(rule, &g, &y, &w0);
            let mut f = w0.clone();
            let fptr = f.data().as_ptr();
            let ws_ptr = ws.out.data().as_ptr();
            update_into(rule, &g, &y, &mut f, &mut ws);
            assert!(f.diff_fro(&want) < 1e-14, "{rule:?}");
            assert_eq!(f.data().as_ptr(), fptr, "{rule:?} moved the factor");
            assert_eq!(ws.out.data().as_ptr(), ws_ptr, "{rule:?} moved scratch");
        }
    }

    #[test]
    fn parse_labels() {
        assert_eq!(UpdateRule::parse("BPP"), Some(UpdateRule::Bpp));
        assert_eq!(UpdateRule::parse("hals"), Some(UpdateRule::Hals));
        assert_eq!(UpdateRule::parse("nope"), None);
    }
}
