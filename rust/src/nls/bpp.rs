//! Block Principal Pivoting NLS solver (Kim & Park, SISC 2011 [33]) — the
//! active-set-like method the paper uses for its ANLS baselines ("To solve
//! the ANLS formulation we use the Block Principle Pivoting (BPP) solver
//! from [33]", §2.1.1).
//!
//! Each row w of the factor solves the QP (App. E)
//!     min_{w ≥ 0} ½ wᵀGw − wᵀy
//! with KKT residual z = Gw − y: find a partition (F, A) with w_A = 0,
//! z_F = 0, w_F = G_FF⁻¹ y_F ≥ 0, z_A = G_AF·w_F − y_A ≥ 0. BPP exchanges
//! *all* infeasible indices at once while that shrinks the infeasible set,
//! falling back to single-index (largest index) exchange otherwise —
//! finite termination is guaranteed.

use crate::linalg::{chol, DenseMat};

/// Solve min_{w≥0} ½wᵀGw − wᵀy for one RHS. `g` must be SPD (the caller
/// regularizes with +αI). Returns the optimal w.
pub fn solve_row(g: &DenseMat, y: &[f64], max_iter: usize) -> Vec<f64> {
    solve_row_from(g, y, vec![false; g.rows()], max_iter)
}

/// BPP from an explicit initial passive set (§Perf: `solve_multi` seeds
/// it with the sign pattern of the unconstrained solution, which is
/// usually one exchange away from optimal).
pub fn solve_row_from(
    g: &DenseMat,
    y: &[f64],
    passive_init: Vec<bool>,
    max_iter: usize,
) -> Vec<f64> {
    let k = g.rows();
    assert_eq!(y.len(), k);
    // passive set flag: true → variable free (in F)
    let mut passive = passive_init;
    let mut w = vec![0.0f64; k];
    let mut z: Vec<f64> = y.iter().map(|&v| -v).collect(); // z = G·0 − y
    // if we start with a non-empty passive set, solve it first so the
    // infeasibility scan below sees consistent (w, z)
    if passive.iter().any(|&p| p) {
        solve_passive(g, y, &passive, &mut w, &mut z);
    }

    // backup-rule state
    let mut alpha = 3usize;
    let mut beta = k + 1; // best (lowest) infeasible count seen

    for _ in 0..max_iter {
        // infeasible sets: V = {i∈F: w_i<0} ∪ {i∈A: z_i<0}
        let mut v: Vec<usize> = Vec::new();
        for i in 0..k {
            if passive[i] && w[i] < 0.0 {
                v.push(i);
            } else if !passive[i] && z[i] < 0.0 {
                v.push(i);
            }
        }
        if v.is_empty() {
            break;
        }
        if v.len() < beta {
            beta = v.len();
            alpha = 3;
            for &i in &v {
                passive[i] = !passive[i];
            }
        } else if alpha > 0 {
            alpha -= 1;
            for &i in &v {
                passive[i] = !passive[i];
            }
        } else {
            // backup rule: flip only the largest infeasible index
            let i = *v.last().unwrap();
            passive[i] = !passive[i];
        }

        solve_passive(g, y, &passive, &mut w, &mut z);
    }
    // numerical cleanup: clamp tiny negatives from the final solve
    for x in w.iter_mut() {
        if *x < 0.0 {
            *x = 0.0;
        }
    }
    w
}

/// Solve the passive subsystem G_FF·w_F = y_F (w_A = 0) and refresh the
/// full KKT residual z = G·w − y.
fn solve_passive(g: &DenseMat, y: &[f64], passive: &[bool], w: &mut [f64], z: &mut [f64]) {
    let k = g.rows();
    let fidx: Vec<usize> = (0..k).filter(|&i| passive[i]).collect();
    w.iter_mut().for_each(|x| *x = 0.0);
    if !fidx.is_empty() {
        let nf = fidx.len();
        let gff = DenseMat::from_fn(nf, nf, |a, b| g.at(fidx[a], fidx[b]));
        let yf: Vec<f64> = fidx.iter().map(|&i| y[i]).collect();
        let sol = match chol::spd_solve(&gff, &yf) {
            Ok(s) => s,
            Err(_) => {
                // jittered retry for numerically singular subsystems
                let (r, _) = chol::cholesky_upper_jittered(&gff);
                chol::solve_upper(&r, &chol::solve_lower_t(&r, &yf))
            }
        };
        for (t, &i) in fidx.iter().enumerate() {
            w[i] = sol[t];
        }
    }
    for i in 0..k {
        let mut s = -y[i];
        for (j, &wj) in w.iter().enumerate() {
            if wj != 0.0 {
                s += g.at(i, j) * wj;
            }
        }
        z[i] = s;
    }
}

/// Multi-RHS BPP: rows of `y` (m×k) are independent QPs sharing G; the
/// result is the m×k nonnegative factor. `warm` (same shape) is accepted
/// for interface parity with HALS/MU but BPP solves each QP exactly from
/// the all-active start (matching [33]).
///
/// Fast path (§Perf): the Cholesky factor of the full G is computed once;
/// each row first tries the unconstrained solution G⁻¹y — if it is
/// already nonnegative it is the (unique) optimum and the active-set
/// machinery is skipped entirely. On converged SymNMF iterates the large
/// majority of rows take this path.
pub fn solve_multi(g: &DenseMat, y: &DenseMat, warm: Option<&DenseMat>) -> DenseMat {
    let mut out = DenseMat::zeros(y.rows(), y.cols());
    solve_multi_into(g, y, warm, &mut out);
    out
}

/// [`solve_multi`] into a pre-allocated m×k output (fully overwritten) —
/// the hot-path form drawing its output from the iteration workspace.
pub fn solve_multi_into(
    g: &DenseMat,
    y: &DenseMat,
    _warm: Option<&DenseMat>,
    out: &mut DenseMat,
) {
    let (m, k) = y.shape();
    assert_eq!(g.shape(), (k, k));
    assert_eq!(out.shape(), (m, k), "solve_multi_into shape");
    let max_iter = 5 * k + 10;
    let (r_full, _eps) = chol::cholesky_upper_jittered(g);
    let mut scratch = vec![0.0f64; k];
    for i in 0..m {
        // unconstrained solve via the cached factor
        scratch.copy_from_slice(y.row(i));
        let yv = chol::solve_lower_t(&r_full, &scratch);
        let x = chol::solve_upper(&r_full, &yv);
        if x.iter().all(|&v| v >= 0.0) {
            out.row_mut(i).copy_from_slice(&x);
        } else {
            // seed BPP with the sign pattern of the unconstrained solve
            let passive: Vec<bool> = x.iter().map(|&v| v > 0.0).collect();
            let w = solve_row_from(g, y.row(i), passive, max_iter);
            out.row_mut(i).copy_from_slice(&w);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;
    use crate::util::propcheck::{dim, forall};
    use crate::util::rng::Pcg64;

    fn spd(k: usize, rng: &mut Pcg64) -> DenseMat {
        let f = DenseMat::gaussian(k + 5, k, rng);
        let mut g = blas::gram(&f);
        for i in 0..k {
            *g.at_mut(i, i) += 0.01;
        }
        g
    }

    /// KKT conditions of the solution must hold.
    #[test]
    fn kkt_property() {
        forall(
            30,
            900,
            |rng| {
                let k = dim(rng, 1, 10);
                let g = spd(k, rng);
                let y: Vec<f64> = rng.gaussian_vec(k);
                (g, y)
            },
            |(g, y)| {
                let k = g.rows();
                let w = solve_row(g, y, 100);
                for i in 0..k {
                    let z: f64 =
                        (0..k).map(|j| g.at(i, j) * w[j]).sum::<f64>() - y[i];
                    if w[i] < -1e-10 {
                        return Err(format!("w[{i}]={} < 0", w[i]));
                    }
                    if z < -1e-7 {
                        return Err(format!("z[{i}]={z} < 0"));
                    }
                    if w[i] * z > 1e-6 {
                        return Err(format!("complementarity w*z={}", w[i] * z));
                    }
                }
                Ok(())
            },
        );
    }

    /// If the unconstrained solution is nonnegative, BPP returns it.
    #[test]
    fn matches_unconstrained_when_interior() {
        let mut rng = Pcg64::seed_from_u64(10);
        for _ in 0..10 {
            let k = 6;
            let g = spd(k, &mut rng);
            let w_true: Vec<f64> = (0..k).map(|_| rng.uniform() + 0.1).collect();
            let y: Vec<f64> = (0..k)
                .map(|i| (0..k).map(|j| g.at(i, j) * w_true[j]).sum())
                .collect();
            let w = solve_row(&g, &y, 100);
            for (a, b) in w.iter().zip(&w_true) {
                assert!((a - b).abs() < 1e-8, "{w:?} vs {w_true:?}");
            }
        }
    }

    /// BPP must beat (or tie) the projected unconstrained solution.
    #[test]
    fn objective_beats_projection_heuristic() {
        let mut rng = Pcg64::seed_from_u64(11);
        let obj = |g: &DenseMat, y: &[f64], w: &[f64]| -> f64 {
            let k = y.len();
            let mut q = 0.0;
            for i in 0..k {
                for j in 0..k {
                    q += 0.5 * w[i] * g.at(i, j) * w[j];
                }
                q -= w[i] * y[i];
            }
            q
        };
        for _ in 0..20 {
            let k = 5;
            let g = spd(k, &mut rng);
            let y: Vec<f64> = rng.gaussian_vec(k);
            let w = solve_row(&g, &y, 100);
            let mut proj = chol::spd_solve(&g, &y).unwrap();
            proj.iter_mut().for_each(|x| *x = x.max(0.0));
            assert!(obj(&g, &y, &w) <= obj(&g, &y, &proj) + 1e-9);
        }
    }

    /// Multi-RHS equals row-by-row NLS against a brute-force active-set
    /// enumeration for tiny k.
    #[test]
    fn matches_bruteforce_small() {
        let mut rng = Pcg64::seed_from_u64(12);
        let k = 3;
        for _ in 0..25 {
            let g = spd(k, &mut rng);
            let y: Vec<f64> = rng.gaussian_vec(k);
            let w = solve_row(&g, &y, 100);
            // brute force over all 2^3 support sets
            let mut best: Option<(f64, Vec<f64>)> = None;
            for mask in 0..(1u32 << k) {
                let fidx: Vec<usize> =
                    (0..k).filter(|&i| mask & (1 << i) != 0).collect();
                let mut cand = vec![0.0; k];
                if !fidx.is_empty() {
                    let nf = fidx.len();
                    let gff =
                        DenseMat::from_fn(nf, nf, |a, b| g.at(fidx[a], fidx[b]));
                    let yf: Vec<f64> = fidx.iter().map(|&i| y[i]).collect();
                    if let Ok(sol) = chol::spd_solve(&gff, &yf) {
                        if sol.iter().any(|&x| x < 0.0) {
                            continue;
                        }
                        for (t, &i) in fidx.iter().enumerate() {
                            cand[i] = sol[t];
                        }
                    } else {
                        continue;
                    }
                }
                let mut q = 0.0;
                for i in 0..k {
                    for j in 0..k {
                        q += 0.5 * cand[i] * g.at(i, j) * cand[j];
                    }
                    q -= cand[i] * y[i];
                }
                if best.as_ref().map(|(b, _)| q < *b).unwrap_or(true) {
                    best = Some((q, cand));
                }
            }
            let (_, wb) = best.unwrap();
            for (a, b) in w.iter().zip(&wb) {
                assert!((a - b).abs() < 1e-7, "bpp {w:?} vs brute {wb:?}");
            }
        }
    }

    #[test]
    fn multi_rhs_shape_and_nonneg() {
        let mut rng = Pcg64::seed_from_u64(13);
        let g = spd(4, &mut rng);
        let y = DenseMat::gaussian(50, 4, &mut rng);
        let w = solve_multi(&g, &y, None);
        assert_eq!(w.shape(), (50, 4));
        assert!(w.is_nonneg());
    }
}
