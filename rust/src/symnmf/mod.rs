//! SymNMF algorithms: the paper's two randomized methods and every
//! baseline they are compared against.
//!
//! * [`anls`] — symmetrically regularized ANLS / HALS / MU (paper §2.1.1,
//!   Eq. 2.3–2.4), the deterministic baseline family.
//! * [`pgncg`] — Projected Gauss–Newton with CG (paper §2.1.3).
//! * [`lai`] — **LAI-SymNMF** (paper §3): SymNMF of a randomized low-rank
//!   approximate input, with Iterative Refinement and Ada-RRF (§3.3), and
//!   LAI-PGNCG (App. B.2).
//! * [`lvs`] — **LvS-SymNMF** (paper §4): leverage-score-sampled NLS
//!   subproblems with hybrid deterministic+random sampling (§4.2).
//! * [`compressed`] — the Compressed-NMF baseline (Tepper & Sapiro [51])
//!   extended to SymNMF (App. B.1).
//!
//! All methods speak [`crate::randnla::SymOp`], share the Update(G, Y)
//! solver toolbox ([`crate::nls`]), the §5 initialization ([`init`]) and
//! the App. C stopping criteria ([`convergence`]); per-iteration metrics
//! land in [`metrics`].

pub mod anls;
pub mod compressed;
pub mod convergence;
pub mod init;
pub mod lai;
pub mod lvs;
pub mod metrics;
pub mod options;
pub mod pgncg;

pub use metrics::{IterRecord, SymNmfResult};
pub use options::SymNmfOptions;
