//! Regenerates paper **Figure 1 + Table 2** (§5.1): convergence curves
//! and summary statistics of {BPP, HALS, PGNCG} × {plain, LAI, LAI-IR,
//! Comp} on the dense WoS-substitute workload, plus the spectral-
//! clustering comparison paragraph.
//!
//! Paper setup: 46,985 docs, 10–20 trials. Testbed scaling: 1,024 docs
//! (matching the AOT artifact shapes), 3 trials (DESIGN.md §3). The
//! *shape* to reproduce: randomized variants 3–7.5× faster at equal
//! Avg-Min-Res / ARI; Comp ≈ LAI; spectral ARI below every SymNMF row.
//!
//!     cargo bench --bench bench_fig1_table2
//! writes results/fig1_convergence.csv and results/table2.txt

use symnmf::clustering::ari::adjusted_rand_index;
use symnmf::coordinator::driver::{
    batch_trials_enabled, packed_x_enabled, run_trials_dense, run_trials_streamed,
};
use symnmf::coordinator::experiments::{fig1_table2_methods, wos_options, wos_workload};
use symnmf::coordinator::report;
use symnmf::symnmf::trace::TraceFormat;
use symnmf::util::rng::Pcg64;
use symnmf::util::timer::Stopwatch;

fn main() {
    let docs = std::env::var("SYMNMF_BENCH_DOCS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1024);
    let trials = std::env::var("SYMNMF_BENCH_TRIALS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);
    // SYMNMF_BATCH_TRIALS=1 runs each method's trials concurrently over
    // the shared adjacency (bitwise-identical factors/residuals; the
    // per-trial `mean_time` column then reflects contended wall clock, so
    // the default stays serial for paper-comparable timings).
    // SYMNMF_PACKED_X=1 additionally stages the adjacency as the
    // packed-triangular SymPacked, so all k seeds share ONE half-sized
    // resident X (see coordinator::driver::run_trials_dense).
    // SYMNMF_STREAM_TRACE=<dir> routes each trial through the serve
    // scheduler with a per-trial streaming JSONL sink: the convergence
    // curves land in <dir>/<label>_t<trial>.jsonl flushed per iteration,
    // so a monitoring tail can plot them MID-RUN instead of waiting for
    // the CSV extracted from the results afterwards (per-seed results
    // stay bitwise-identical; timings reflect shared-machine wall clock
    // like the batched driver).
    let batched = batch_trials_enabled();
    let stream_dir = std::env::var("SYMNMF_STREAM_TRACE")
        .ok()
        .filter(|s| !s.is_empty());

    println!(
        "== Fig. 1 / Table 2 bench: WoS dense workload ({docs} docs, {trials} trials{}{}{}) ==",
        if batched { ", batched" } else { "" },
        if packed_x_enabled() { ", packed X" } else { "" },
        if stream_dir.is_some() { ", streaming traces" } else { "" }
    );
    let w = wos_workload(docs, 1);
    let mut opts = wos_options().with_seed(10);
    opts.max_iters = 150;

    let mut all = Vec::new();
    for method in fig1_table2_methods() {
        let t = Stopwatch::start();
        let stats = match &stream_dir {
            Some(dir) => run_trials_streamed(
                method,
                &w.adjacency,
                &opts,
                Some(&w.labels),
                trials,
                std::path::Path::new(dir),
                TraceFormat::Jsonl,
            )
            .expect("streaming trial driver"),
            None => run_trials_dense(
                method,
                &w.adjacency,
                &opts,
                Some(&w.labels),
                trials,
                batched,
            ),
        };
        println!(
            "  {:<14} mean {:5.1} iters  {:7.3}s  min-res {:.4}  ARI {:.3}  [bench wall {:.1}s]",
            stats.label,
            stats.mean_iters,
            stats.mean_time,
            stats.min_res,
            stats.mean_ari,
            t.elapsed_secs()
        );
        all.push(stats);
    }

    // spectral comparison (§5.1.1 ¶)
    let mut rng = Pcg64::seed_from_u64(99);
    let t = Stopwatch::start();
    let mut aris = Vec::new();
    for _ in 0..trials {
        let assign =
            symnmf::clustering::spectral::spectral_cluster(&w.adjacency, 7, &mut rng);
        aris.push(adjusted_rand_index(&assign, &w.labels));
    }
    let spectral_ari = aris.iter().sum::<f64>() / aris.len() as f64;
    let spectral_secs = t.elapsed_secs() / trials as f64;

    let table = report::stats_table(&all);
    let speedups = report::speedups_vs(&all, "BPP");
    let summary = format!(
        "{table}\n{speedups}\nSpectral clustering: mean ARI {spectral_ari:.4} in {spectral_secs:.2}s/run \
         (paper: 0.293, worse than all SymNMF rows)\n"
    );
    println!("\n{summary}");

    std::fs::create_dir_all("results").ok();
    report::write_convergence_csv(std::path::Path::new("results/fig1_convergence.csv"), &all)
        .unwrap();
    std::fs::write("results/table2.txt", &summary).unwrap();
    println!("wrote results/fig1_convergence.csv, results/table2.txt");
}
