//! Restart recovery: rebuild a serve fleet from whatever a crashed
//! process left in its [`JobStore`].
//!
//! A crash can interrupt the store at any byte: the atomic temp+rename
//! discipline in [`JobStore::save`] makes a torn file at a *final* path
//! unlikely, but not impossible (filesystems without durable rename,
//! operator error, disk corruption). Recovery therefore trusts nothing:
//! [`scan`] walks every job id found on disk and, per job, inspects the
//! generations **newest → oldest**:
//!
//! 1. The first generation that parses becomes the job's resume
//!    checkpoint ([`RecoveredJob::checkpoint`]).
//! 2. Every newer generation that fails to read or parse is
//!    **quarantined**: renamed in place to `<file>.corrupt` — never
//!    deleted, so a post-mortem can inspect exactly what the crash tore.
//!    The `.corrupt` suffix takes the file out of the store's
//!    `*.ckpt.json` namespace, so [`JobStore::generations`], GC, and
//!    future saves all ignore it (and a re-save of the same generation
//!    number cannot collide with it).
//! 3. A job none of whose generations parse is reported with no
//!    checkpoint — the caller restarts it **cold**. Because a fresh
//!    deterministic run and a checkpoint-resumed run both reproduce the
//!    uninterrupted iteration sequence bitwise (the engine contract),
//!    either path converges to the same factors; only the wasted work
//!    differs.
//!
//! The CLI face is `symnmf serve --recover` (see `main.rs`): it scans
//! the store before submission, resubmits each spec'd job from its
//! newest valid generation, prints a [`RecoveryReport`], and embeds the
//! same counts in the version-3 JSON report.

use crate::serve::store::{sanitize_id, JobStore};
use crate::symnmf::engine::Checkpoint;
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// One job's recovery result: the newest parseable generation (if any)
/// and the corrupt files moved out of the way to reach it.
pub struct RecoveredJob {
    /// Sanitized job id, as found in the store's filenames.
    pub id: String,
    /// `(generation, checkpoint)` to resume from; `None` → restart cold.
    pub checkpoint: Option<(u64, Checkpoint)>,
    /// Final paths of quarantined (renamed, never deleted) generations.
    pub quarantined: Vec<PathBuf>,
}

/// Everything a store scan found, keyed for spec-side lookup.
pub struct RecoveryScan {
    /// Per-job results, sorted by sanitized id.
    pub jobs: Vec<RecoveredJob>,
}

impl RecoveryScan {
    /// The recovered checkpoint for a *raw* (unsanitized) job id.
    pub fn checkpoint_for(&self, raw_id: &str) -> Option<&(u64, Checkpoint)> {
        let id = sanitize_id(raw_id);
        self.jobs
            .iter()
            .find(|j| j.id == id)
            .and_then(|j| j.checkpoint.as_ref())
    }

    /// Total quarantined files across all jobs.
    pub fn files_quarantined(&self) -> usize {
        self.jobs.iter().map(|j| j.quarantined.len()).sum()
    }
}

/// Counts for the operator: how the fleet restarted.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RecoveryReport {
    /// Jobs resubmitted from a persisted generation.
    pub jobs_recovered: usize,
    /// Jobs restarted from scratch (nothing valid on disk).
    pub jobs_cold: usize,
    /// Unparseable generation files renamed to `*.corrupt`.
    pub files_quarantined: usize,
}

impl RecoveryReport {
    pub fn render(&self) -> String {
        format!(
            "recovery: {} job(s) resumed from persisted checkpoints, \
             {} restarted cold, {} corrupt file(s) quarantined",
            self.jobs_recovered, self.jobs_cold, self.files_quarantined
        )
    }

    /// The `recovery` object of the version-3 serve JSON report.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("jobs_recovered", Json::Num(self.jobs_recovered as f64)),
            ("jobs_cold", Json::Num(self.jobs_cold as f64)),
            ("files_quarantined", Json::Num(self.files_quarantined as f64)),
        ])
    }
}

/// Quarantine name of a generation file: the same path with `.corrupt`
/// appended — outside the `*.ckpt.json` namespace, same directory (so
/// the rename never crosses a filesystem).
fn quarantine_path(path: &Path) -> PathBuf {
    let name = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_default();
    path.with_file_name(format!("{name}.corrupt"))
}

/// Recover one job: walk its generations newest → oldest, quarantining
/// unreadable files, until one parses (or none do). Errors only on an
/// I/O failure of the quarantine rename itself or of the directory scan
/// — a corrupt checkpoint is an expected input, not an error.
pub fn recover_job(store: &JobStore, id: &str) -> Result<RecoveredJob, String> {
    let gens = store.generations(id)?;
    let mut quarantined = Vec::new();
    let mut checkpoint = None;
    for &gen in gens.iter().rev() {
        let path = store.path_for(id, gen);
        let parsed = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {path:?}: {e}"))
            .and_then(|text| {
                Checkpoint::parse(&text).map_err(|e| format!("parse {path:?}: {e}"))
            });
        match parsed {
            Ok(cp) => {
                checkpoint = Some((gen, cp));
                break;
            }
            Err(why) => {
                let corrupt = quarantine_path(&path);
                std::fs::rename(&path, &corrupt).map_err(|e| {
                    format!("quarantine {path:?} -> {corrupt:?}: {e} (file was corrupt: {why})")
                })?;
                eprintln!(
                    "[recover] {id}: generation {gen} unreadable ({why}); \
                     quarantined as {corrupt:?}"
                );
                quarantined.push(corrupt);
            }
        }
    }
    Ok(RecoveredJob { id: sanitize_id(id), checkpoint, quarantined })
}

/// Scan the whole store: every job id with at least one generation on
/// disk is recovered (quarantining as it goes). Ids are discovered from
/// the filenames, so jobs persisted by a crashed process are found even
/// if the current spec no longer mentions them.
pub fn scan(store: &JobStore) -> Result<RecoveryScan, String> {
    let mut jobs = Vec::new();
    for id in store.job_ids()? {
        jobs.push(recover_job(store, &id)?);
    }
    Ok(RecoveryScan { jobs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::DenseMat;
    use crate::symnmf::engine::{EngineState, RunStatus};
    use crate::util::rng::Pcg64;

    fn tmp_store(name: &str) -> JobStore {
        let dir = std::env::temp_dir()
            .join(format!("symnmf-recover-{}-{name}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        JobStore::open(&dir).expect("open store").with_keep(4)
    }

    fn sample_cp(seed: u64, iters: usize) -> Checkpoint {
        let mut rng = Pcg64::seed_from_u64(seed);
        Checkpoint {
            status: RunStatus::Paused,
            stage: 0,
            stage_iter: iters,
            iter: iters,
            clock: 0.25,
            stop_best: 0.5,
            stop_stall: 0,
            state: EngineState {
                h: DenseMat::gaussian(5, 2, &mut rng),
                w: None,
                rng: None,
            },
            records: Vec::new(),
            isa: Some("scalar".to_string()),
        }
    }

    #[test]
    fn clean_store_recovers_newest_with_no_quarantine() {
        let store = tmp_store("clean");
        store.save("j", 1, &sample_cp(1, 1), true).unwrap();
        store.save("j", 2, &sample_cp(2, 2), true).unwrap();
        let r = recover_job(&store, "j").unwrap();
        let (gen, cp) = r.checkpoint.expect("recovered");
        assert_eq!((gen, cp.iter), (2, 2));
        assert!(r.quarantined.is_empty());
        std::fs::remove_dir_all(store.dir()).ok();
    }

    #[test]
    fn corrupt_newest_is_quarantined_not_deleted_and_older_resumes() {
        let store = tmp_store("corrupt");
        store.save("j", 1, &sample_cp(1, 1), true).unwrap();
        store.save("j", 2, &sample_cp(2, 2), true).unwrap();
        store.save("j", 3, &sample_cp(3, 3), true).unwrap();
        let g3 = store.path_for("j", 3);
        let torn = std::fs::read_to_string(&g3).unwrap();
        std::fs::write(&g3, &torn[..torn.len() / 3]).unwrap();
        let r = recover_job(&store, "j").unwrap();
        let (gen, cp) = r.checkpoint.expect("fallback generation");
        assert_eq!((gen, cp.iter), (2, 2));
        // quarantined: renamed, never deleted, bytes intact
        assert_eq!(r.quarantined.len(), 1);
        assert!(!g3.exists(), "corrupt file must leave the store namespace");
        let q = &r.quarantined[0];
        assert!(q.to_string_lossy().ends_with(".corrupt"), "{q:?}");
        assert_eq!(
            std::fs::read_to_string(q).unwrap(),
            torn[..torn.len() / 3],
            "quarantine preserves the evidence"
        );
        // the store no longer sees the quarantined generation
        assert_eq!(store.generations("j").unwrap(), vec![1, 2]);
        std::fs::remove_dir_all(store.dir()).ok();
    }

    #[test]
    fn all_generations_corrupt_means_cold_restart() {
        let store = tmp_store("cold");
        store.save("j", 1, &sample_cp(1, 1), true).unwrap();
        store.save("j", 2, &sample_cp(2, 2), true).unwrap();
        for g in [1u64, 2] {
            std::fs::write(store.path_for("j", g), "not json").unwrap();
        }
        let r = recover_job(&store, "j").unwrap();
        assert!(r.checkpoint.is_none(), "nothing valid: cold restart");
        assert_eq!(r.quarantined.len(), 2);
        assert!(store.generations("j").unwrap().is_empty());
        std::fs::remove_dir_all(store.dir()).ok();
    }

    #[test]
    fn scan_covers_every_job_and_report_counts_add_up() {
        let store = tmp_store("scan");
        store.save("good", 1, &sample_cp(1, 1), true).unwrap();
        store.save("torn", 1, &sample_cp(2, 1), true).unwrap();
        store.save("torn", 2, &sample_cp(3, 2), true).unwrap();
        std::fs::write(store.path_for("torn", 2), "{{").unwrap();
        store.save("dead", 1, &sample_cp(4, 1), true).unwrap();
        std::fs::write(store.path_for("dead", 1), "").unwrap();
        let scan = scan(&store).unwrap();
        assert_eq!(scan.jobs.len(), 3);
        assert_eq!(scan.files_quarantined(), 2);
        assert_eq!(scan.checkpoint_for("good").map(|(g, _)| *g), Some(1));
        assert_eq!(scan.checkpoint_for("torn").map(|(g, _)| *g), Some(1));
        assert!(scan.checkpoint_for("dead").is_none());
        assert!(scan.checkpoint_for("ghost").is_none());
        // raw → sanitized lookup goes through sanitize_id
        assert_eq!(scan.checkpoint_for("go od").map(|(g, _)| *g), None);
        let report = RecoveryReport {
            jobs_recovered: 2,
            jobs_cold: 1,
            files_quarantined: scan.files_quarantined(),
        };
        assert!(report.render().contains("2 job(s) resumed"));
        let j = report.to_json();
        assert_eq!(j.get("files_quarantined").and_then(Json::as_usize), Some(2));
        std::fs::remove_dir_all(store.dir()).ok();
    }
}
