"""AOT path sanity: lowering emits parseable HLO text with the expected
entry signature, and the manifest enumeration is consistent."""

import jax
import jax.numpy as jnp

from compile import aot, model


def test_to_hlo_text_products_smoke():
    lowered = jax.jit(model.products).lower(
        jax.ShapeDtypeStruct((16, 16), jnp.float32),
        jax.ShapeDtypeStruct((16, 4), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[16,16]" in text
    assert "f32[16,4]" in text
    # return_tuple=True → root is a tuple of the two outputs
    assert "(f32[16,4]" in text and "f32[4,4]" in text


def test_build_entries_consistent():
    entries = aot.build_entries()
    names = [e["name"] for e in entries]
    assert len(names) == len(set(names)), "artifact names must be unique"
    for e in entries:
        assert len(e["inputs"]) == len(e["args"])
        for shp, arg in zip(e["inputs"], e["args"]):
            assert tuple(shp) == tuple(arg.shape)


def test_hals_sweep_lowers():
    m, k = 16, 4
    lowered = jax.jit(model.hals_sweep).lower(
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, k), jnp.float32),
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "while" in text  # fori_loop lowers to an HLO while loop
