//! **LAI-SymNMF** (paper §3, Alg. LAI-SymNMF): compute a randomized
//! approximate truncated EVD X ≈ U·Λ·Uᵀ once (Apx-EVD over RRF/Ada-RRF),
//! then run any SymNMF iteration against the factored input, where the
//! bottleneck product X·F becomes U·(Vᵀ·F) at O(mlk) instead of O(m²k).
//!
//! Practical considerations of §3.3 are both implemented:
//! * **Ada-RRF** — adaptive choice of the power-iteration count q;
//! * **Iterative Refinement (IR)** — after the LAI iterations converge,
//!   continue with the true X under the same stopping rule.

use crate::linalg::{blas, DenseMat};
use crate::randnla::evd::{apx_evd, apx_evd_adaptive, ApxEvd};
use crate::randnla::SymOp;
use crate::symnmf::anls::{resolve_alpha, run_alternating_loop, Metrics};
use crate::symnmf::init::initial_factor;
use crate::symnmf::metrics::SymNmfResult;
use crate::symnmf::options::{PowerIter, SymNmfOptions};
use crate::util::rng::Pcg64;
use crate::util::timer::{PhaseTimer, Stopwatch, PHASE_MM};

/// The factored low-rank approximate input X ≈ U·Vᵀ (V = U·Λ) as a
/// [`SymOp`]: `apply` costs two skinny matmuls.
pub struct LaiOp {
    pub u: DenseMat,
    pub v: DenseMat,
    fro_sq: f64,
    max_v: f64,
    mean_v: f64,
}

impl LaiOp {
    /// Wrap an approximate EVD; `alpha_source` supplies max/mean of the
    /// TRUE X so that α and the init scale match the exact algorithms.
    pub fn new<X: SymOp>(evd: &ApxEvd, alpha_source: &X) -> LaiOp {
        LaiOp {
            u: evd.u.clone(),
            v: evd.v(),
            fro_sq: evd.fro_norm_sq(),
            max_v: alpha_source.max_value(),
            mean_v: alpha_source.mean_value(),
        }
    }
}

impl SymOp for LaiOp {
    fn dim(&self) -> usize {
        self.u.rows()
    }

    fn apply(&self, f: &DenseMat) -> DenseMat {
        // U·(Vᵀ·F): (l×k) inner product then (m×l)(l×k)
        let vtf = blas::matmul_tn(&self.v, f);
        blas::matmul(&self.u, &vtf)
    }

    fn fro_norm_sq(&self) -> f64 {
        self.fro_sq
    }

    fn max_value(&self) -> f64 {
        self.max_v
    }

    fn mean_value(&self) -> f64 {
        self.mean_v
    }

    fn sampled_apply(&self, f: &DenseMat, samples: &[usize], weights_sq: &[f64]) -> DenseMat {
        // V·SᵀS·F ... not used by LAI-SymNMF; provide the generic form
        // U·(VᵀSᵀ)(S F) for completeness.
        let sv = self.v.gather_rows_scaled(samples, &weights_sq.iter().map(|w| w.sqrt()).collect::<Vec<_>>());
        let sf = f.gather_rows_scaled(samples, &weights_sq.iter().map(|w| w.sqrt()).collect::<Vec<_>>());
        let inner = blas::matmul_tn(&sv, &sf);
        blas::matmul(&self.u, &inner)
    }
}

/// Build the LAI (Apx-EVD) per the options' power policy, timing it as
/// setup + MM work.
pub fn build_lai<X: SymOp>(
    x: &X,
    opts: &SymNmfOptions,
    rng: &mut Pcg64,
    phases: &mut PhaseTimer,
) -> (LaiOp, f64, ApxEvd) {
    let sw = Stopwatch::start();
    let l = opts.sketch_width();
    let evd = match opts.power {
        PowerIter::Static(q) => apx_evd(x, l, q, rng),
        PowerIter::Adaptive { q_max, tol } => apx_evd_adaptive(x, l, q_max, tol, rng),
    };
    let secs = sw.elapsed_secs();
    phases.add(PHASE_MM, std::time::Duration::from_secs_f64(secs));
    (LaiOp::new(&evd, x), secs, evd)
}

/// LAI-SymNMF with alternating updates (Alg. LAI-SymNMF); set
/// `opts.refine` for the "-IR" variants of §5.1.
pub fn lai_symnmf<X: SymOp>(x: &X, opts: &SymNmfOptions) -> SymNmfResult {
    let mut rng = Pcg64::seed_from_u64(opts.seed);
    let alpha = resolve_alpha(x, opts);
    let mut phases = PhaseTimer::new();
    let (lai, setup_secs, _evd) = build_lai(x, opts, &mut rng, &mut phases);
    let h0 = initial_factor(x, opts, &mut rng);
    let metrics = Metrics::new(x, true);

    let base_label = format!("LAI-{}", opts.rule.label());
    let mut result = run_alternating_loop(
        &lai,
        alpha,
        opts,
        h0,
        &metrics,
        base_label.clone(),
        setup_secs,
        phases,
    );

    if opts.refine {
        // Iterative Refinement: same loop, true X, warm start, clock
        // carries on from where LAI stopped.
        let clock = result.total_secs();
        let h_warm = result.h.clone();
        let refined = run_alternating_loop(
            x.as_dyn(),
            alpha,
            opts,
            h_warm,
            &metrics,
            format!("{base_label}-IR"),
            clock,
            result.phases.clone(),
        );
        // stitch the iteration logs together
        let mut records = result.records;
        let offset = records.len();
        records.extend(refined.records.into_iter().map(|mut r| {
            r.iter += offset;
            r
        }));
        return SymNmfResult {
            label: format!("{base_label}-IR"),
            h: refined.h,
            w: refined.w,
            records,
            phases: refined.phases,
            setup_secs,
        };
    }
    result.label = base_label;
    result
}

/// Helper: view a concrete SymOp as a trait object (run_alternating_loop
/// takes &dyn).
trait AsDyn: SymOp + Sized {
    fn as_dyn(&self) -> &dyn SymOp {
        self
    }
}
impl<T: SymOp> AsDyn for T {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nls::UpdateRule;
    use crate::symnmf::anls::symnmf_anls;

    fn planted(m: usize, k: usize, seed: u64) -> DenseMat {
        let mut rng = Pcg64::seed_from_u64(seed);
        let h = DenseMat::uniform(m, k, 1.0, &mut rng);
        let mut x = blas::matmul_nt(&h, &h);
        x.symmetrize();
        x
    }

    #[test]
    fn lai_op_approximates_apply() {
        let x = planted(80, 4, 1);
        let mut rng = Pcg64::seed_from_u64(2);
        let opts = SymNmfOptions::new(4);
        let mut phases = PhaseTimer::new();
        let (lai, _secs, _evd) = build_lai(&x, &opts, &mut rng, &mut phases);
        let f = DenseMat::gaussian(80, 4, &mut rng);
        let exact = SymOp::apply(&x, &f);
        let approx = lai.apply(&f);
        let rel = exact.diff_fro(&approx) / exact.fro_norm();
        assert!(rel < 1e-6, "planted rank-4 ⊂ l=12 sketch: rel={rel}");
    }

    #[test]
    fn lai_symnmf_matches_exact_quality_on_low_rank() {
        let x = planted(70, 4, 3);
        for rule in [UpdateRule::Bpp, UpdateRule::Hals] {
            let mut opts = SymNmfOptions::new(4).with_rule(rule).with_seed(7);
            opts.max_iters = 120;
            let exact = symnmf_anls(&x, &opts);
            let lai = lai_symnmf(&x, &opts);
            assert!(lai.h.is_nonneg());
            assert!(
                lai.min_residual() < exact.min_residual() + 0.05,
                "{rule:?}: LAI {} vs exact {}",
                lai.min_residual(),
                exact.min_residual()
            );
            assert!(lai.setup_secs > 0.0);
        }
    }

    #[test]
    fn ir_continues_and_improves_or_matches() {
        let x = planted(60, 3, 4);
        let mut opts = SymNmfOptions::new(3).with_seed(8);
        opts.max_iters = 60;
        opts.refine = false;
        let plain = lai_symnmf(&x, &opts);
        opts.refine = true;
        let ir = lai_symnmf(&x, &opts);
        assert!(ir.label.ends_with("-IR"));
        assert!(ir.iters() >= plain.iters(), "IR adds iterations");
        assert!(ir.min_residual() <= plain.min_residual() + 1e-6);
    }

    #[test]
    fn clock_includes_setup() {
        let x = planted(50, 3, 5);
        let mut opts = SymNmfOptions::new(3);
        opts.max_iters = 5;
        let res = lai_symnmf(&x, &opts);
        assert!(res.records[0].time_secs >= res.setup_secs);
    }
}
