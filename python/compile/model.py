"""L2: the JAX compute graphs AOT-compiled for the rust coordinator.

Three programs (DESIGN.md §5), each calling the L1 Pallas kernels so that
the kernels lower into the same HLO module:

  products(x, f)          → (X·F, FᵀF)        — one half-iteration of
                             ANLS/HALS/PGNCG, and one RRF power step.
  lai_products(u, v, f)   → (U·(Vᵀ·F), FᵀF)   — one half-iteration of
                             LAI-SymNMF against the factored input UVᵀ≈X.
  hals_sweep(xh,g,w,h,α)  → W′                 — a full fused column sweep
                             of the regularized symmetric HALS update
                             (paper Eq. 2.6) via lax.fori_loop.

Python runs only at build time (`make artifacts`); the rust runtime loads
the lowered HLO text and executes it through PJRT.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import matmul as kmatmul


def products(x: jax.Array, f: jax.Array):
    """(X·F, FᵀF) with both products computed by Pallas kernels.

    X is the (m, m) symmetric data matrix, F an (m, k) factor (H or W, or
    an (m, l) sketch block during RRF power iterations).
    """
    xf = kmatmul.matmul(x, f)
    g = kmatmul.gram(f)
    return xf, g


def lai_products(u: jax.Array, v: jax.Array, f: jax.Array):
    """(U·(Vᵀ·F), FᵀF) — the LAI replacement for X·F (paper Alg. LAI-SymNMF
    lines 7/10): with X ≈ U·Vᵀ (V = UΛ from Apx-EVD), X·F ≈ U(VᵀF) costs
    O(mlk) instead of O(m²k)."""
    vtf = kmatmul.matmul(v.transpose(), f)   # (l, k) — small
    uvtf = kmatmul.matmul(u, vtf)            # (m, k)
    g = kmatmul.gram(f)
    return uvtf, g


def hals_sweep(xh: jax.Array, g: jax.Array, w: jax.Array, h: jax.Array,
               alpha: jax.Array):
    """One full sweep of the modified regularized HALS update (Eq. 2.6):

        w_i ← [ ((XH)_i − W·G_i + α h_i)/(G_ii + α) + (G_ii/(G_ii+α)) w_i ]_+

    sequentially over i = 1..k (columns updated in place — later columns see
    earlier updates through W·G_i).  XH and G = HᵀH are computed once by
    `products`; this sweep is O(mk²) and fuses the whole inner loop into a
    single XLA while-loop so the rust hot path makes one PJRT call per sweep.
    """
    k = w.shape[1]

    def body(i, w):
        gcol = lax.dynamic_slice_in_dim(g, i, 1, axis=1)[:, 0]       # (k,)
        gii = gcol[i]
        denom = gii + alpha
        xh_i = lax.dynamic_slice_in_dim(xh, i, 1, axis=1)[:, 0]      # (m,)
        h_i = lax.dynamic_slice_in_dim(h, i, 1, axis=1)[:, 0]
        w_i = lax.dynamic_slice_in_dim(w, i, 1, axis=1)[:, 0]
        numer = xh_i - w @ gcol + alpha * h_i
        wi_new = jnp.maximum(numer / denom + (gii / denom) * w_i, 0.0)
        return lax.dynamic_update_slice_in_dim(w, wi_new[:, None], i, axis=1)

    return lax.fori_loop(0, k, body, w)
