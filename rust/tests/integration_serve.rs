//! Serving-layer acceptance: jobs driven through the scheduler in
//! budgeted slices — including a mid-flight cancel and a resume — must
//! reproduce the uninterrupted solve **bitwise** for every method, and
//! checkpoints/traces must survive the filesystem round trip.

use symnmf::coordinator::driver::Method;
use symnmf::linalg::{blas, DenseMat, SymPacked};
use symnmf::nls::UpdateRule;
use symnmf::serve::{
    CachedOperator, JobSpec, JobStatus, JobStore, OpCache, OpCacheConfig, OpKey, Scheduler,
    SchedulerConfig,
};
use symnmf::symnmf::options::{SymNmfOptions, Tau};
use symnmf::symnmf::trace::TraceFormat;
use symnmf::symnmf::SymNmfResult;
use symnmf::util::json::Json;
use symnmf::util::rng::Pcg64;

fn planted(m: usize, k: usize, seed: u64) -> DenseMat {
    let mut rng = Pcg64::seed_from_u64(seed);
    let h = DenseMat::uniform(m, k, 1.0, &mut rng);
    let mut x = blas::matmul_nt(&h, &h);
    x.symmetrize();
    x
}

/// Bitwise equality of everything the engine contract pins (wall-clock
/// fields exempt) — a local copy of the crate-internal test helper.
fn assert_bitwise(a: &SymNmfResult, b: &SymNmfResult, what: &str) {
    assert_eq!(a.label, b.label, "{what}: label");
    assert_eq!(a.iters(), b.iters(), "{what}: iteration count");
    for (i, (ra, rb)) in a.records.iter().zip(&b.records).enumerate() {
        assert_eq!(ra.iter, rb.iter, "{what}: record {i} index");
        assert_eq!(
            ra.residual.to_bits(),
            rb.residual.to_bits(),
            "{what}: residual at iter {i}"
        );
        assert_eq!(
            ra.proj_grad.map(f64::to_bits),
            rb.proj_grad.map(f64::to_bits),
            "{what}: proj_grad at iter {i}"
        );
        assert_eq!(
            ra.hybrid_stats.map(|(p, q)| (p.to_bits(), q.to_bits())),
            rb.hybrid_stats.map(|(p, q)| (p.to_bits(), q.to_bits())),
            "{what}: hybrid stats at iter {i}"
        );
    }
    assert_eq!(a.h.shape(), b.h.shape(), "{what}: H shape");
    for (x, y) in a.h.data().iter().zip(b.h.data()) {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: H bits");
    }
    assert_eq!(a.w.shape(), b.w.shape(), "{what}: W shape");
    for (x, y) in a.w.data().iter().zip(b.w.data()) {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: W bits");
    }
}

fn methods_under_test() -> Vec<Method> {
    vec![
        Method::Exact(UpdateRule::Bpp),
        Method::Exact(UpdateRule::Hals),
        Method::Lai { rule: UpdateRule::Hals, refine: true },
        Method::Comp(UpdateRule::Hals),
        Method::Pgncg,
        Method::LaiPgncg { refine: true },
        Method::Lvs { rule: UpdateRule::Hals, tau: Tau::OneOverS },
    ]
}

fn opts_for(k: usize, m: usize) -> SymNmfOptions {
    let mut opts = SymNmfOptions::new(k).with_seed(5);
    opts.max_iters = 8;
    opts.samples = Some(m / 2); // LvS sample budget on these small m
    opts.cg_iters = 5;
    opts
}

/// THE acceptance criterion: for every method at k ∈ {2, 7}, a job
/// driven through the scheduler in ≥ 3 slices — one of which is cut by a
/// mid-flight cancel, then resumed — produces bitwise-identical H, W,
/// and residual history to the uninterrupted [`Method::run`] call.
#[test]
fn every_method_sliced_cancelled_resumed_is_bitwise_exact() {
    for k in [2usize, 7] {
        let m = 10 * k;
        let x = planted(m, k, 100 + k as u64);
        let opts = opts_for(k, m);
        for method in methods_under_test() {
            let what = format!("{} k={k}", method.label());
            let full = method.run(&x, &opts);

            let mut sched = Scheduler::new(SchedulerConfig {
                slice_steps: Some(2),
                ..SchedulerConfig::default()
            });
            let spec = JobSpec::new("acceptance", method, opts.clone())
                .with_cancel_after(3);
            let h = sched.submit(&x, spec).expect("submit");
            sched.drain();
            assert_eq!(h.poll(), JobStatus::Cancelled, "{what}: cancel hook");
            let mid = h.outcome().expect("cancelled outcome");
            assert_eq!(
                mid.expect_checkpoint().iter, 3,
                "{what}: the hook fires after record 3, the engine aborts \
                 before step 4"
            );
            sched.resume(&h).expect("resume");
            sched.drain();
            let done = h.await_result();
            assert_eq!(done.status, JobStatus::Completed, "{what}");
            assert!(
                done.slices >= 3,
                "{what}: needs >= 3 slices, got {}",
                done.slices
            );
            assert_bitwise(&full, done.expect_result(), &what);
        }
    }
}

/// Checkpoints survive the store round trip across *scheduler restarts*:
/// suspend a job, build a fresh scheduler over the same store, resume
/// from the persisted generation, and land bitwise on the uninterrupted
/// run. Also pins generation GC.
#[test]
fn store_backed_restart_resumes_bitwise_and_gcs() {
    let dir = std::env::temp_dir()
        .join(format!("symnmf-serve-it-store-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let x = planted(30, 3, 77);
    let mut opts = SymNmfOptions::new(3).with_seed(2);
    opts.max_iters = 7;
    let method = Method::Exact(UpdateRule::Hals);
    let full = method.run(&x, &opts);

    // session 1: 2-step slices, suspend after 4 steps, checkpoints persisted
    {
        let store = JobStore::open(&dir).expect("open store");
        let mut sched = Scheduler::new(SchedulerConfig {
            slice_steps: Some(2),
            store: Some(store),
            ..SchedulerConfig::default()
        });
        let h = sched
            .submit(&x, JobSpec::new("restartable", method, opts.clone()).with_max_steps(4))
            .expect("submit");
        sched.drain();
        let o = h.await_result();
        assert_eq!(o.status, JobStatus::Suspended);
        assert_eq!(o.expect_checkpoint().iter, 4);
    }

    // the store holds exactly one (GC'd) generation for the job
    let store = JobStore::open(&dir).expect("reopen store");
    let gens = store.generations("restartable").expect("generations");
    assert_eq!(gens.len(), 1, "superseded generations must be GC'd: {gens:?}");
    let (_, cp) = store.load_latest("restartable").expect("load").expect("present");
    assert_eq!(cp.iter, 4);

    // session 2: a fresh scheduler (fresh process in real life) over the
    // SAME store resumes from the persisted checkpoint and completes
    // bitwise — and its new generations must continue ABOVE the
    // persisted numbering, or GC would delete the fresh checkpoints in
    // favor of the stale pre-restart one
    let gen_before = *gens.last().unwrap();
    {
        let store = JobStore::open(&dir).expect("open store again");
        let mut sched = Scheduler::new(SchedulerConfig {
            store: Some(store),
            ..SchedulerConfig::default()
        });
        let h = sched
            .submit(
                &x,
                JobSpec::new("restartable", method, opts.clone()).with_resume(cp),
            )
            .expect("submit resumed");
        sched.drain();
        let o = h.await_result();
        assert_eq!(o.status, JobStatus::Completed);
        assert_bitwise(&full, o.expect_result(), "store-backed restart");
    }
    let store = JobStore::open(&dir).expect("final reopen");
    let gens = store.generations("restartable").expect("generations");
    assert_eq!(gens.len(), 1);
    assert!(
        gens[0] > gen_before,
        "restart must continue generation numbering ({} !> {gen_before})",
        gens[0]
    );
    let (_, final_cp) = store.load_latest("restartable").expect("load").expect("present");
    assert_eq!(
        final_cp.iter,
        full.iters(),
        "the retained generation is the completed state, not the stale one"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// A slim (factor-only) store still resumes to bitwise-identical factors
/// and future residuals; only the pre-resume history is absent from the
/// final result (it lives in the trace stream instead).
#[test]
fn slim_store_resumes_factors_bitwise() {
    let dir = std::env::temp_dir()
        .join(format!("symnmf-serve-it-slim-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let x = planted(28, 2, 55);
    let mut opts = SymNmfOptions::new(2).with_seed(9);
    opts.max_iters = 6;
    let method = Method::Exact(UpdateRule::Bpp);
    let full = method.run(&x, &opts);
    {
        let store = JobStore::open(&dir).expect("open store");
        let mut sched = Scheduler::new(SchedulerConfig {
            store: Some(store),
            slim_checkpoints: true,
            ..SchedulerConfig::default()
        });
        let h = sched
            .submit(&x, JobSpec::new("slim-job", method, opts.clone()).with_max_steps(3))
            .expect("submit");
        sched.drain();
        assert_eq!(h.await_result().status, JobStatus::Suspended);
    }
    let store = JobStore::open(&dir).expect("reopen");
    let (_, cp) = store.load_latest("slim-job").expect("load").expect("present");
    assert!(cp.records.is_empty(), "slim checkpoint drops the history");
    assert_eq!(cp.iter, 3);
    let mut sched = Scheduler::new(SchedulerConfig::default());
    let h = sched
        .submit(&x, JobSpec::new("slim-job", method, opts).with_resume(cp))
        .expect("submit");
    sched.drain();
    let o = h.await_result();
    assert_eq!(o.status, JobStatus::Completed);
    let res = o.expect_result();
    // records: only the post-resume tail, globally numbered
    assert_eq!(res.records.first().map(|r| r.iter), Some(3));
    let tail = &full.records[3..];
    assert_eq!(res.records.len(), tail.len());
    for (a, b) in tail.iter().zip(&res.records) {
        assert_eq!(a.residual.to_bits(), b.residual.to_bits(), "slim resume residuals");
    }
    for (a, b) in full.h.data().iter().zip(res.h.data()) {
        assert_eq!(a.to_bits(), b.to_bits(), "slim resume H bits");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// A job's persistent JSONL trace stitched across slices (including a
/// cancel + resume) equals the uninterrupted run's residual history,
/// record for record, bitwise (via the residual_hex field).
#[test]
fn stitched_trace_stream_equals_uninterrupted_history() {
    let dir = std::env::temp_dir()
        .join(format!("symnmf-serve-it-trace-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).expect("mkdir");
    let trace_path = dir.join("job.jsonl");
    let x = planted(30, 3, 91);
    let mut opts = SymNmfOptions::new(3).with_seed(6);
    opts.max_iters = 7;
    let method = Method::Exact(UpdateRule::Hals);
    let full = method.run(&x, &opts);

    let mut sched = Scheduler::new(SchedulerConfig {
        slice_steps: Some(2),
        ..SchedulerConfig::default()
    });
    let spec = JobSpec::new("traced", method, opts)
        .with_cancel_after(3)
        .with_trace(trace_path.clone(), TraceFormat::Jsonl);
    let h = sched.submit(&x, spec).expect("submit");
    sched.drain();
    assert_eq!(h.poll(), JobStatus::Cancelled);
    sched.resume(&h).expect("resume");
    sched.drain();
    let o = h.await_result();
    assert_eq!(o.status, JobStatus::Completed);

    let text = std::fs::read_to_string(&trace_path).expect("trace file");
    let iters: Vec<(usize, String)> = text
        .lines()
        .map(|l| Json::parse(l).expect("parseable trace line"))
        .filter(|j| j.get("type").and_then(Json::as_str) == Some("iter"))
        .map(|j| {
            (
                j.get("iter").and_then(Json::as_usize).unwrap(),
                j.get("residual_hex").and_then(Json::as_str).unwrap().to_string(),
            )
        })
        .collect();
    assert_eq!(
        iters.len(),
        full.iters(),
        "stitched stream must cover the whole history exactly once"
    );
    for (i, (r, (iter, hex))) in full.records.iter().zip(&iters).enumerate() {
        assert_eq!(r.iter, *iter, "record {i} numbering");
        assert_eq!(
            &format!("{:016x}", r.residual.to_bits()),
            hex,
            "record {i} residual"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// PR-7 acceptance: a concurrent multi-graph serve under a resident-bytes
/// ceiling smaller than the working set. Two distinct packed graphs share
/// a cache that can hold only one of them, four sliced jobs churn the
/// cache (evict → spill → fault back between slices), and every job must
/// still land **bitwise** on its uninterrupted [`Method::run`] over the
/// resident operator — plus the ceiling must hold once the fleet drains.
#[test]
fn budgeted_multi_graph_serve_is_bitwise_and_holds_the_ceiling() {
    let dir = std::env::temp_dir()
        .join(format!("symnmf-serve-it-budget-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).expect("mkdir");

    let graphs: Vec<DenseMat> = vec![planted(48, 3, 71), planted(48, 3, 72)];
    let packed: Vec<SymPacked> = graphs.iter().map(SymPacked::from_dense).collect();
    let keys: Vec<OpKey> = packed.iter().map(OpKey::of_packed).collect();
    assert_ne!(keys[0], keys[1], "distinct graphs must hash distinctly");
    let op_bytes =
        CachedOperator::Packed(SymPacked::from_dense(&graphs[0])).resident_payload_bytes();

    let mut opts = SymNmfOptions::new(3).with_seed(4);
    opts.max_iters = 8;
    opts.tol = 0.0; // run all 8 iterations: every job takes >= 4 slices
    let methods = [Method::Exact(UpdateRule::Hals), Method::Exact(UpdateRule::Bpp)];
    // uninterrupted references over the RESIDENT packed operator: the
    // spilled tier must reproduce these bits through any eviction schedule
    let full: Vec<Vec<_>> = (0..2)
        .map(|g| methods.iter().map(|m| m.run(&packed[g], &opts)).collect())
        .collect();

    // budget fits exactly one operator — two graphs in flight guarantee
    // eviction churn between slices
    let mut cfg = OpCacheConfig::new(dir.clone());
    cfg.budget_bytes = Some(op_bytes + 1);
    let cache = std::sync::Arc::new(OpCache::new(cfg));

    let mut sched = Scheduler::new(SchedulerConfig {
        slice_steps: Some(2),
        ..SchedulerConfig::default()
    });
    let mut handles = Vec::new();
    for g in 0..2usize {
        for (mi, method) in methods.iter().enumerate() {
            let x = graphs[g].clone();
            let spec = JobSpec::new(format!("g{g}-m{mi}"), *method, opts.clone());
            let h = sched
                .submit_cached(
                    &cache,
                    keys[g].clone(),
                    move || CachedOperator::Packed(SymPacked::from_dense(&x)),
                    spec,
                )
                .expect("submit");
            handles.push((g, mi, h));
        }
    }
    sched.drain();

    // after the drain every pin is released, so the ceiling must hold
    // and (with two operators built) at least one graph is now on disk
    let s = cache.stats();
    assert_eq!(s.misses, 2, "each graph builds exactly once: {s:?}");
    assert!(s.evictions >= 1, "ceiling must force eviction: {s:?}");
    assert!(s.spill_writes >= 1, "packed eviction must spill: {s:?}");
    assert!(
        s.resident_bytes <= op_bytes + 1,
        "drained cache must respect the ceiling: {s:?}"
    );

    // second wave: one more job per graph — whichever graph the first
    // wave left spilled is now deterministically served from disk
    for g in 0..2usize {
        let x = graphs[g].clone();
        let spec = JobSpec::new(format!("g{g}-w2"), methods[0], opts.clone());
        let h = sched
            .submit_cached(
                &cache,
                keys[g].clone(),
                move || CachedOperator::Packed(SymPacked::from_dense(&x)),
                spec,
            )
            .expect("submit wave 2");
        handles.push((g, 0, h));
    }
    sched.drain();

    let mut spilled_slices = 0;
    for (g, mi, h) in &handles {
        let o = h.await_result();
        assert_eq!(o.status, JobStatus::Completed, "g{g}-m{mi}");
        assert!(o.slices >= 3, "g{g}-m{mi}: sliced run expected, got {}", o.slices);
        spilled_slices += o.spilled_slices;
        assert_bitwise(&full[*g][*mi], o.expect_result(), &format!("g{g}-m{mi} budgeted"));
    }

    let s = cache.stats();
    assert_eq!(s.misses, 2, "spill-eviction must never force a rebuild: {s:?}");
    assert!(s.spilled_hits >= 1, "some slice must fault from disk: {s:?}");
    assert_eq!(
        spilled_slices as u64, s.spilled_hits,
        "per-job spilled-slice accounting must match the cache's count"
    );
    assert!(
        s.resident_bytes <= op_bytes + 1,
        "drained cache must respect the ceiling: {s:?}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// PR-8 acceptance (a): a panic injected into ONE job's slice fails that
/// job alone. Every other job in the fleet lands bitwise on its
/// uninjected reference run, and — the `@2` trigger being spent — the
/// victim resumes from its last good checkpoint to the same bits.
#[test]
fn injected_panic_isolates_the_victim_and_spares_the_fleet() {
    let k = 3usize;
    let x = planted(30, k, 131);
    let opts_of = |seed: u64| {
        let mut o = SymNmfOptions::new(k).with_seed(seed);
        o.max_iters = 8;
        o.tol = 0.0; // fixed length: every job takes >= 4 slices
        o
    };
    let method = Method::Exact(UpdateRule::Hals);
    let names = ["it-iso-a", "it-iso-victim", "it-iso-b"];
    let seeds = [11u64, 12, 13];
    let full: Vec<SymNmfResult> =
        seeds.iter().map(|&s| method.run(&x, &opts_of(s))).collect();

    // per-key arm: only the job literally named it-iso-victim ever
    // matches "slice:it-iso-victim", so the fleet shares the scheduler
    // with a live fail point that cannot touch it
    let _fp = symnmf::util::failpoint::scoped("slice:it-iso-victim=panic@2");
    let mut sched = Scheduler::new(SchedulerConfig {
        slice_steps: Some(2),
        ..SchedulerConfig::default()
    });
    let handles: Vec<_> = names
        .iter()
        .zip(&seeds)
        .map(|(n, &s)| sched.submit(&x, JobSpec::new(*n, method, opts_of(s))).expect("submit"))
        .collect();
    sched.drain();

    let v1 = handles[1].await_result();
    assert_eq!(v1.status, JobStatus::Failed, "victim must fail");
    let msg = v1.failure.as_deref().expect("failure message");
    assert!(msg.contains("injected panic"), "{msg}");
    assert_eq!(v1.expect_checkpoint().iter, 2, "slice 1 survived the panic");
    for &i in &[0usize, 2] {
        let o = handles[i].await_result();
        assert_eq!(o.status, JobStatus::Completed, "{} must be unaffected", names[i]);
        assert!(o.failure.is_none());
        assert_bitwise(&full[i], o.expect_result(), names[i]);
    }

    sched.resume(&handles[1]).expect("failed jobs are resumable");
    sched.drain();
    let v2 = handles[1].await_result();
    assert_eq!(v2.status, JobStatus::Completed);
    assert!(v2.failure.is_none(), "resume clears the failure");
    assert_bitwise(&full[1], v2.expect_result(), "resumed victim");
}

/// PR-8 acceptance (b): abort a store-backed drain mid-flight via a fail
/// point, tear the newest persisted generation on disk, and recover in a
/// fresh scheduler: the torn file is quarantined (renamed `*.corrupt`,
/// never deleted), the older generation resumes, and every job's final
/// factors are bitwise-identical to the uninterrupted run.
#[test]
fn crash_recovery_quarantines_and_reproduces_bitwise() {
    let dir = std::env::temp_dir()
        .join(format!("symnmf-serve-it-recover-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let x = planted(30, 3, 171);
    let opts_of = |seed: u64| {
        let mut o = SymNmfOptions::new(3).with_seed(seed);
        o.max_iters = 8;
        o.tol = 0.0;
        o
    };
    let method = Method::Exact(UpdateRule::Hals);
    let full = [method.run(&x, &opts_of(21)), method.run(&x, &opts_of(22))];

    // session 1: store-backed fleet (keep 2); it-rec-crash "crashes" at
    // the start of its third slice, it-rec-ok suspends cleanly at step 4
    {
        let store = JobStore::open(&dir).expect("open store").with_keep(2);
        let _fp = symnmf::util::failpoint::scoped("slice:it-rec-crash=panic@3");
        let mut sched = Scheduler::new(SchedulerConfig {
            slice_steps: Some(2),
            store: Some(store),
            ..SchedulerConfig::default()
        });
        let ha = sched
            .submit(&x, JobSpec::new("it-rec-ok", method, opts_of(21)).with_max_steps(4))
            .expect("submit");
        let hb = sched
            .submit(&x, JobSpec::new("it-rec-crash", method, opts_of(22)))
            .expect("submit");
        sched.drain();
        assert_eq!(ha.await_result().status, JobStatus::Suspended);
        let ob = hb.await_result();
        assert_eq!(ob.status, JobStatus::Failed);
        assert_eq!(ob.expect_checkpoint().iter, 4, "two good slices persisted");
    }

    // tear the newest generation of it-rec-ok: recovery must quarantine
    // it and fall back to the older one
    let store = JobStore::open(&dir).expect("reopen").with_keep(2);
    let gens = store.generations("it-rec-ok").expect("gens");
    assert_eq!(gens.len(), 2, "keep=2 retains both slice generations");
    let newest = store.path_for("it-rec-ok", *gens.last().unwrap());
    let text = std::fs::read_to_string(&newest).expect("read newest");
    std::fs::write(&newest, &text[..text.len() / 2]).expect("tear");

    let scan = symnmf::serve::recovery::scan(&store).expect("scan");
    assert_eq!(scan.files_quarantined(), 1);
    let rec = scan.jobs.iter().find(|j| j.id == "it-rec-ok").expect("scanned");
    let q = &rec.quarantined[0];
    assert!(q.to_string_lossy().ends_with(".corrupt"), "{q:?}");
    assert!(q.exists(), "quarantined file must be renamed, not deleted");
    let (gen_ok, cp_ok) = scan.checkpoint_for("it-rec-ok").expect("fallback gen").clone();
    assert_eq!((gen_ok, cp_ok.iter), (gens[0], 2), "older generation survives");
    let (_, cp_crash) = scan.checkpoint_for("it-rec-crash").expect("crash gen").clone();
    assert_eq!(cp_crash.iter, 4, "the crash job recovers its newest generation");

    // session 2: a fresh scheduler (fresh process in real life) resumes
    // both jobs from their recovered checkpoints and completes bitwise
    let mut sched = Scheduler::new(SchedulerConfig {
        store: Some(store),
        ..SchedulerConfig::default()
    });
    let ha = sched
        .submit(&x, JobSpec::new("it-rec-ok", method, opts_of(21)).with_resume(cp_ok))
        .expect("submit recovered");
    let hb = sched
        .submit(&x, JobSpec::new("it-rec-crash", method, opts_of(22)).with_resume(cp_crash))
        .expect("submit recovered");
    sched.drain();
    for (h, f, what) in [
        (&ha, &full[0], "recovered it-rec-ok"),
        (&hb, &full[1], "recovered it-rec-crash"),
    ] {
        let o = h.await_result();
        assert_eq!(o.status, JobStatus::Completed, "{what}");
        assert_bitwise(f, o.expect_result(), what);
    }
    std::fs::remove_dir_all(&dir).ok();
}
