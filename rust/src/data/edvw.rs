//! EDVW hypergraph → symmetric adjacency (paper §5.1, methodology of
//! Hayashi, Aksoy, Park & Park, CIKM'20 [27]).
//!
//! Documents are vertices, terms are hyperedges, and the tf-idf value is
//! the edge-dependent vertex weight γ_e(v). The clique expansion with
//! EDVW gives the dense symmetric similarity matrix
//!
//! ```text
//!     A = Γᵀ · diag(ω_e / δ_e) · Γ,   δ_e = Σ_v γ_e(v),  ω_e = 1,
//! ```
//!
//! ("each hyperedge is expanded into a weighted clique" — §5.1), followed
//! by the [35] preprocessing: zeroed diagonal + symmetric normalization.
//! The result is dense (m×m), exactly the §5.1 regime.

use crate::linalg::DenseMat;
use crate::sparse::CsrMat;

/// Build the dense EDVW adjacency from a docs×terms tf-idf matrix.
pub fn edvw_adjacency(tfidf: &CsrMat) -> DenseMat {
    let m = tfidf.rows();
    let t = tfidf.cols();
    // hyperedge degrees δ_e = Σ_v γ_e(v): column sums
    let mut delta = vec![0.0f64; t];
    for d in 0..m {
        let (cols, vals) = tfidf.row(d);
        for (&e, &v) in cols.iter().zip(vals) {
            delta[e] += v;
        }
    }
    // A = Σ_e (1/δ_e) γ_e γ_eᵀ — accumulate per hyperedge via a
    // transposed (terms→docs) pass to keep it O(Σ_e |e|²).
    let trans = transpose_csr(tfidf);
    let mut a = DenseMat::zeros(m, m);
    for e in 0..t {
        if delta[e] <= 0.0 {
            continue;
        }
        let (docs, gammas) = trans.row(e);
        let inv = 1.0 / delta[e];
        for (p, (&di, &gi)) in docs.iter().zip(gammas).enumerate() {
            let wi = gi * inv;
            // symmetric accumulation: handle pairs (p, q≥p)
            for (&dj, &gj) in docs[p..].iter().zip(&gammas[p..]) {
                let v = wi * gj;
                *a.at_mut(di, dj) += v;
                if di != dj {
                    *a.at_mut(dj, di) += v;
                }
            }
        }
    }
    // §5 preprocessing: zero diagonal, symmetric normalization
    for i in 0..m {
        a.set(i, i, 0.0);
    }
    let deg: Vec<f64> = (0..m)
        .map(|i| a.row(i).iter().sum::<f64>())
        .collect();
    let dinv: Vec<f64> = deg
        .iter()
        .map(|&d| if d > 0.0 { 1.0 / d.sqrt() } else { 0.0 })
        .collect();
    for i in 0..m {
        let di = dinv[i];
        for j in 0..m {
            *a.at_mut(i, j) *= di * dinv[j];
        }
    }
    a
}

fn transpose_csr(x: &CsrMat) -> CsrMat {
    let mut trips = Vec::with_capacity(x.nnz());
    for i in 0..x.rows() {
        let (cols, vals) = x.row(i);
        for (&j, &v) in cols.iter().zip(vals) {
            trips.push((j, i, v));
        }
    }
    CsrMat::from_coo(x.cols(), x.rows(), trips)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{generate, tfidf, CorpusParams};

    #[test]
    fn adjacency_is_symmetric_nonneg_zero_diag() {
        let c = generate(&CorpusParams {
            num_docs: 50,
            num_terms: 150,
            num_topics: 5,
            doc_len: 40,
            noise: 0.2,
            topic_mix: 0.0,
            seed: 1,
        });
        let w = tfidf(&c.counts);
        let a = edvw_adjacency(&w);
        assert_eq!(a.shape(), (50, 50));
        assert!(a.is_nonneg());
        for i in 0..50 {
            assert_eq!(a.at(i, i), 0.0);
            for j in 0..50 {
                assert!((a.at(i, j) - a.at(j, i)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn same_topic_docs_are_more_similar() {
        let c = generate(&CorpusParams {
            num_docs: 60,
            num_terms: 300,
            num_topics: 3,
            doc_len: 60,
            noise: 0.1,
            topic_mix: 0.0,
            seed: 2,
        });
        let w = tfidf(&c.counts);
        let a = edvw_adjacency(&w);
        // average within-topic vs cross-topic similarity
        let mut within = (0.0, 0usize);
        let mut across = (0.0, 0usize);
        for i in 0..60 {
            for j in (i + 1)..60 {
                if c.labels[i] == c.labels[j] {
                    within.0 += a.at(i, j);
                    within.1 += 1;
                } else {
                    across.0 += a.at(i, j);
                    across.1 += 1;
                }
            }
        }
        let w_avg = within.0 / within.1 as f64;
        let a_avg = across.0 / across.1 as f64;
        assert!(
            w_avg > 3.0 * a_avg,
            "within {w_avg} should dominate across {a_avg}"
        );
    }

    #[test]
    fn clique_expansion_matches_dense_formula() {
        // tiny hand case: A = Γᵀ diag(1/δ) Γ with diagonal zeroed + norm
        let g = CsrMat::from_coo(
            3,
            2,
            vec![(0, 0, 1.0), (1, 0, 2.0), (1, 1, 1.0), (2, 1, 3.0)],
        );
        let a = edvw_adjacency(&g);
        // edge 0: docs {0(1), 1(2)}, δ=3 → A01 += 1·2/3
        // edge 1: docs {1(1), 2(3)}, δ=4 → A12 += 1·3/4
        // before normalization: A01 = 2/3, A12 = 3/4, A02 = 0
        let a01: f64 = 2.0 / 3.0;
        let a12 = 0.75;
        let d0 = a01;
        let d1 = a01 + a12;
        let d2 = a12;
        let want01 = a01 / ((d0 * d1) as f64).sqrt();
        let want12 = a12 / ((d1 * d2) as f64).sqrt();
        assert!((a.at(0, 1) - want01).abs() < 1e-12);
        assert!((a.at(1, 2) - want12).abs() < 1e-12);
        assert_eq!(a.at(0, 2), 0.0);
    }
}
