//! Shared configuration for all SymNMF solvers.

use crate::linalg::Precision;
use crate::nls::UpdateRule;

/// Options shared by the ANLS/HALS/PGNCG/LAI/LvS drivers. Defaults follow
/// the paper's experimental setup (§5).
#[derive(Clone, Debug)]
pub struct SymNmfOptions {
    /// target rank k
    pub k: usize,
    /// regularization α of Eq. 2.3; `None` → α = max(X) (§5.1, from [35])
    pub alpha: Option<f64>,
    /// update rule for alternating methods
    pub rule: UpdateRule,
    /// hard iteration cap
    pub max_iters: usize,
    /// stopping: residual must drop by more than `tol` ...
    pub tol: f64,
    /// ... within `patience` consecutive iterations (§5.1 uses 1e-4 / 4)
    pub patience: usize,
    /// PRNG seed (initialization + any sketching)
    pub seed: u64,

    // --- randomized-method knobs ---
    /// column oversampling ρ; l = k + ρ (§3.3 recommends ρ ∈ [2k, 3k])
    pub rho: usize,
    /// power iterations: `Static(q)` or `Adaptive { q_max, tol }` (Ada-RRF)
    pub power: PowerIter,
    /// run Iterative Refinement after LAI converges (§3.3)
    pub refine: bool,
    /// LvS: number of row samples s; `None` → ⌈0.05·m⌉ (§5.2)
    pub samples: Option<usize>,
    /// LvS: hybrid threshold τ (τ = 1 → pure random; §5.2 uses 1/s)
    pub tau: Tau,
    /// PGNCG: CG iterations per outer step
    pub cg_iters: usize,
    /// optional warm-start factor H₀ (m×k); overrides the §5 random init.
    /// Used e.g. to study the hybrid sampler along a converged trajectory
    /// (Fig. 6) or to chain solvers.
    pub warm_start: Option<crate::linalg::DenseMat>,
    /// compute precision of the **sketched** inner GEMMs (Compressed /
    /// LAI apply only — dense methods, Gram accumulation, and the
    /// residual/stopping rule always run in f64). `None` defers to the
    /// `SYMNMF_PRECISION` environment variable (unset → f64). Not part
    /// of the checkpoint: resuming is only bitwise under identical
    /// options, and precision is an option like any other.
    pub precision: Option<Precision>,
}

/// Power-iteration policy for the range finder.
#[derive(Clone, Copy, Debug)]
pub enum PowerIter {
    /// fixed q (the q=2 of prior work; Table 6 ablation)
    Static(usize),
    /// Ada-RRF: iterate until the QB residual stops improving by `tol`
    Adaptive { q_max: usize, tol: f64 },
}

/// Hybrid-sampling threshold policy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Tau {
    /// fixed τ value (τ = 1.0 disables deterministic inclusion)
    Fixed(f64),
    /// τ = 1/s — the paper's sparse-experiment setting
    OneOverS,
}

impl Tau {
    pub fn value(&self, s: usize) -> f64 {
        match self {
            Tau::Fixed(t) => *t,
            Tau::OneOverS => 1.0 / s.max(1) as f64,
        }
    }
}

impl SymNmfOptions {
    pub fn new(k: usize) -> Self {
        SymNmfOptions {
            k,
            alpha: None,
            rule: UpdateRule::Bpp,
            max_iters: 300,
            tol: 1e-4,
            patience: 4,
            seed: 0,
            rho: 2 * k,
            // Ada-RRF improvement threshold: the paper uses 1e-3 on WoS;
            // our synthetic spectra have a long flat tail where sub-5e-3
            // per-iteration improvements never pay back their O(m²l)
            // cost, so the default is coarser (the knob is exposed).
            power: PowerIter::Adaptive { q_max: 8, tol: 2e-3 },
            refine: false,
            samples: None,
            tau: Tau::OneOverS,
            cg_iters: 20,
            warm_start: None,
            precision: None,
        }
    }

    pub fn with_rule(mut self, rule: UpdateRule) -> Self {
        self.rule = rule;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.precision = Some(precision);
        self
    }

    /// The compute precision the sketched pipelines should use: the
    /// explicit option if set, else `SYMNMF_PRECISION` (unset → f64).
    pub fn resolved_precision(&self) -> Precision {
        self.precision.unwrap_or_else(Precision::from_env)
    }

    /// l = k + ρ, the sketch width.
    pub fn sketch_width(&self) -> usize {
        self.k + self.rho
    }

    /// Effective sample count for an m-row problem.
    pub fn effective_samples(&self, m: usize) -> usize {
        self.samples.unwrap_or(((m as f64) * 0.05).ceil() as usize).max(self.k + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let o = SymNmfOptions::new(7);
        assert_eq!(o.rho, 14, "ρ defaults to 2k");
        assert_eq!(o.sketch_width(), 21);
        assert_eq!(o.tol, 1e-4);
        assert_eq!(o.patience, 4);
        assert_eq!(o.effective_samples(1000), 50, "s = 0.05·m");
        assert!(matches!(o.power, PowerIter::Adaptive { .. }));
    }

    #[test]
    fn tau_policies() {
        assert_eq!(Tau::Fixed(1.0).value(100), 1.0);
        assert_eq!(Tau::OneOverS.value(200), 0.005);
    }

    #[test]
    fn samples_floor_is_k_plus_one() {
        let o = SymNmfOptions::new(16);
        assert_eq!(o.effective_samples(10), 17);
    }

    #[test]
    fn precision_explicit_option_wins_over_env_default() {
        let o = SymNmfOptions::new(4);
        assert!(o.precision.is_none(), "default defers to SYMNMF_PRECISION");
        let o = o.with_precision(Precision::F32);
        assert_eq!(o.resolved_precision(), Precision::F32);
        let o = o.with_precision(Precision::F64);
        assert_eq!(o.resolved_precision(), Precision::F64);
    }
}
