"""AOT-lower the L2 programs to HLO text artifacts for the rust runtime.

Interchange format is HLO *text*, not serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the XLA
behind the published `xla` 0.1.6 crate) rejects (`proto.id() <= INT_MAX`).
The text parser reassigns ids, so text round-trips cleanly.
See /opt/xla-example/README.md.

Usage (from python/):  python -m compile.aot --out-dir ../artifacts

Writes one ``<program>_<shape>.hlo.txt`` per entry in SHAPES plus a
``manifest.json`` the rust artifact registry (rust/src/runtime/registry.rs)
reads at startup.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the rust
    side unwraps a single tuple result)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


# ---------------------------------------------------------------------------
# Shape set (DESIGN.md §5): a small test set exercised by the rust
# integration tests, plus the end-to-end example / bench shapes.
# products is also compiled at width l (= k + ρ) because RRF power
# iterations reuse it for X·Q with Q ∈ R^{m×l}.
# ---------------------------------------------------------------------------

PRODUCTS = [(64, 8), (64, 24), (1024, 7), (1024, 21)]
LAI_PRODUCTS = [(64, 24, 8), (1024, 21, 7)]
HALS_SWEEP = [(64, 8), (1024, 7)]


def build_entries():
    entries = []
    for m, k in PRODUCTS:
        entries.append(dict(
            program="products", name=f"products_m{m}_k{k}",
            fn=model.products, args=[spec(m, m), spec(m, k)],
            dims=dict(m=m, k=k),
            inputs=[[m, m], [m, k]], outputs=[[m, k], [k, k]],
        ))
    for m, l, k in LAI_PRODUCTS:
        entries.append(dict(
            program="lai_products", name=f"lai_products_m{m}_l{l}_k{k}",
            fn=model.lai_products, args=[spec(m, l), spec(m, l), spec(m, k)],
            dims=dict(m=m, l=l, k=k),
            inputs=[[m, l], [m, l], [m, k]], outputs=[[m, k], [k, k]],
        ))
    for m, k in HALS_SWEEP:
        entries.append(dict(
            program="hals_sweep", name=f"hals_sweep_m{m}_k{k}",
            fn=model.hals_sweep,
            args=[spec(m, k), spec(k, k), spec(m, k), spec(m, k), spec()],
            dims=dict(m=m, k=k),
            inputs=[[m, k], [k, k], [m, k], [m, k], []], outputs=[[m, k]],
        ))
    return entries


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []
    for e in build_entries():
        lowered = jax.jit(e["fn"]).lower(*e["args"])
        text = to_hlo_text(lowered)
        fname = e["name"] + ".hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest.append(dict(
            program=e["program"], file=fname, dims=e["dims"],
            inputs=e["inputs"], outputs=e["outputs"], dtype="f32",
        ))
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump({"version": 1, "artifacts": manifest}, f, indent=1)
    print(f"wrote manifest with {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
