//! Scoped data-parallel helpers (rayon is unavailable offline).
//!
//! `parallel_for_chunks` splits an index range into contiguous chunks and
//! runs them on `std::thread::scope` workers. On a 1-core image it
//! degrades gracefully to a sequential loop with no thread spawns; on
//! multicore machines the dense kernels in `linalg::blas`, the CSR SpMM,
//! and the batched trial driver pick it up.
//!
//! The worker count is resolved **once per process** (see
//! [`num_threads`]) and chunk sizes are balanced to within one element,
//! so the partitioning seen by every kernel is deterministic — a property
//! the batched multi-seed driver relies on for bitwise-reproducible
//! trials.

use std::sync::OnceLock;

/// Raw mutable pointer wrapper so disjoint index ranges of one output
/// buffer can be written from scoped worker threads. Shared by the dense
/// kernels, the CSR SpMM, and the HALS sweep.
///
/// SAFETY contract for users: every worker must write only through
/// offsets derived from its own disjoint `(lo, hi)` range, and the
/// pointee must outlive the parallel call (guaranteed by
/// `std::thread::scope`).
#[derive(Clone, Copy)]
pub(crate) struct SendPtr(pub(crate) *mut f64);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Cached worker count, resolved on first use. `parallel_for_chunks` is
/// called from inside every hot kernel, so re-reading (and re-parsing)
/// the environment per call would put a syscall on the per-iteration
/// path.
static NUM_THREADS: OnceLock<usize> = OnceLock::new();

/// Number of worker threads to use: `SYMNMF_THREADS` env or available
/// parallelism. Resolved once per process and cached — changing the
/// environment variable after the first kernel call has no effect.
pub fn num_threads() -> usize {
    *NUM_THREADS.get_or_init(|| {
        if let Ok(v) = std::env::var("SYMNMF_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    })
}

/// The `c`-th of `chunks` balanced contiguous ranges covering `0..n`:
/// the first `n % chunks` ranges get one extra element, so sizes differ
/// by at most one. The previous `div_ceil` sizing gave every chunk
/// ⌈n/chunks⌉ elements and dumped the shortfall on the tail — e.g. 97
/// rows over 4 workers split 25/25/25/22, and 9 rows over 8 workers left
/// 3 workers with nothing at all. Balanced sizing keeps the slowest
/// worker's share minimal, which matters when the chunk body is the
/// memory-bound inner loop of a kernel.
fn chunk_range(n: usize, chunks: usize, c: usize) -> (usize, usize) {
    debug_assert!(chunks >= 1 && c < chunks);
    let base = n / chunks;
    let rem = n % chunks;
    let lo = c * base + c.min(rem);
    let hi = lo + base + usize::from(c < rem);
    (lo, hi)
}

/// Run `body(lo, hi)` over disjoint subranges covering `0..n` in parallel.
/// `body` must be safe to run concurrently on disjoint ranges.
pub fn parallel_for_chunks<F>(n: usize, min_chunk: usize, body: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let nt = num_threads();
    if nt <= 1 || n <= min_chunk {
        body(0, n);
        return;
    }
    let chunks = nt.min(n.div_ceil(min_chunk)).max(1);
    std::thread::scope(|s| {
        for c in 0..chunks {
            let (lo, hi) = chunk_range(n, chunks, c);
            if lo >= hi {
                continue;
            }
            let body = &body;
            s.spawn(move || body(lo, hi));
        }
    });
}

/// Map over `0..n`, writing results into a pre-allocated vec (each index
/// written exactly once by one worker).
pub fn parallel_map_into<T: Send + Sync, F>(out: &mut [T], min_chunk: usize, f: F)
where
    F: Fn(usize, &mut T) + Sync,
{
    let n = out.len();
    let nt = num_threads();
    if nt <= 1 || n <= min_chunk {
        for (i, slot) in out.iter_mut().enumerate() {
            f(i, slot);
        }
        return;
    }
    let chunks = nt.min(n.div_ceil(min_chunk)).max(1);
    std::thread::scope(|s| {
        // split_at_mut based partitioning, balanced to within one element;
        // chunk_range tiles 0..n contiguously, so `lo` is each chunk's
        // global base index.
        let mut rest = out;
        for c in 0..chunks {
            let (lo, hi) = chunk_range(n, chunks, c);
            if lo >= hi {
                continue;
            }
            let (head, tail) = rest.split_at_mut(hi - lo);
            rest = tail;
            let f = &f;
            s.spawn(move || {
                for (i, slot) in head.iter_mut().enumerate() {
                    f(lo + i, slot);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn covers_all_indices_once() {
        let n = 1000;
        let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for_chunks(n, 10, |lo, hi| {
            for i in lo..hi {
                counts[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn map_into_writes_each_slot() {
        let mut out = vec![0usize; 257];
        parallel_map_into(&mut out, 8, |i, slot| *slot = i * 2);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * 2);
        }
    }

    #[test]
    fn empty_range_ok() {
        parallel_for_chunks(0, 1, |_, _| panic!("must not be called"));
    }

    #[test]
    fn num_threads_is_cached_and_positive() {
        let a = num_threads();
        let b = num_threads();
        assert!(a >= 1);
        assert_eq!(a, b, "cached value must be stable");
    }

    /// Balanced split: ranges tile 0..n exactly and sizes differ by ≤ 1.
    #[test]
    fn chunk_ranges_are_balanced() {
        for n in [1usize, 2, 7, 130, 1000, 1025] {
            for chunks in 1..=8usize.min(n) {
                let mut next = 0usize;
                let mut sizes = Vec::new();
                for c in 0..chunks {
                    let (lo, hi) = chunk_range(n, chunks, c);
                    assert_eq!(lo, next, "ranges must tile contiguously");
                    assert!(hi >= lo);
                    sizes.push(hi - lo);
                    next = hi;
                }
                assert_eq!(next, n, "ranges must cover 0..n");
                let max = *sizes.iter().max().unwrap();
                let min = *sizes.iter().min().unwrap();
                assert!(max - min <= 1, "n={n} chunks={chunks}: {sizes:?}");
            }
        }
    }
}
